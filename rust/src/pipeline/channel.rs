//! Bandwidth-shaped channels between pipeline workers.
//!
//! In `real` mode messages deliver immediately (host memory).  In
//! `emulate` mode each directed worker pair behaves like a serialised
//! D2D link with finite bandwidth and latency — the same model as the
//! simulator's `LinkSet`, but applied to live traffic so the real
//! pipeline reproduces edge-network behaviour on a single host.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Network emulation parameters for one directed link.
#[derive(Debug, Clone)]
pub struct LinkModel {
    pub bytes_per_sec: f64,
    pub latency_s: f64,
}

/// Shared serialisation state of one directed link.
pub struct Shaper {
    model: LinkModel,
    /// Seconds-from-epoch at which the link frees up.
    free_at: Mutex<f64>,
    epoch: Instant,
}

impl Shaper {
    pub fn new(model: LinkModel, epoch: Instant) -> Arc<Shaper> {
        Arc::new(Shaper { model, free_at: Mutex::new(0.0), epoch })
    }

    /// Register a transfer of `bytes` now; returns the delivery instant.
    pub fn send(&self, bytes: usize) -> Instant {
        let now = self.epoch.elapsed().as_secs_f64();
        let mut free = self.free_at.lock().unwrap();
        let start = free.max(now);
        let end = start + bytes as f64 / self.model.bytes_per_sec;
        *free = end;
        self.epoch + Duration::from_secs_f64(end + self.model.latency_s)
    }
}

/// Sending half: optionally shaped.
pub struct Tx<M> {
    inner: mpsc::Sender<(Instant, M)>,
    shaper: Option<Arc<Shaper>>,
}

impl<M> Clone for Tx<M> {
    fn clone(&self) -> Self {
        Tx { inner: self.inner.clone(), shaper: self.shaper.clone() }
    }
}

impl<M> Tx<M> {
    /// Send a message of `bytes` logical size.
    pub fn send(&self, bytes: usize, msg: M) -> anyhow::Result<()> {
        let at = match &self.shaper {
            Some(s) => s.send(bytes),
            None => Instant::now(),
        };
        self.inner
            .send((at, msg))
            .map_err(|_| anyhow::anyhow!("channel closed"))
    }

    /// Attach a shaper (per directed link) to this sender handle.
    pub fn shaped(&self, shaper: Arc<Shaper>) -> Tx<M> {
        Tx { inner: self.inner.clone(), shaper: Some(shaper) }
    }
}

/// Receiving half: honours per-message delivery instants.
pub struct Rx<M> {
    inner: mpsc::Receiver<(Instant, M)>,
}

impl<M> Rx<M> {
    /// Blocking receive; sleeps until the message's delivery time.
    pub fn recv(&self) -> anyhow::Result<M> {
        let (at, msg) = self
            .inner
            .recv()
            .map_err(|_| anyhow::anyhow!("channel closed"))?;
        let now = Instant::now();
        if at > now {
            std::thread::sleep(at - now);
        }
        Ok(msg)
    }

    /// Non-blocking receive of already-delivered messages.
    pub fn try_recv(&self) -> Option<M> {
        match self.inner.try_recv() {
            Ok((at, msg)) => {
                let now = Instant::now();
                if at > now {
                    std::thread::sleep(at - now);
                }
                Some(msg)
            }
            Err(_) => None,
        }
    }
}

/// Create an unshaped channel pair.
pub fn channel<M>() -> (Tx<M>, Rx<M>) {
    let (tx, rx) = mpsc::channel();
    (Tx { inner: tx, shaper: None }, Rx { inner: rx })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unshaped_delivers_immediately() {
        let (tx, rx) = channel();
        tx.send(1_000_000, 42u32).unwrap();
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn shaped_delays_by_bandwidth() {
        let epoch = Instant::now();
        let shaper = Shaper::new(
            LinkModel { bytes_per_sec: 1e6, latency_s: 0.0 },
            epoch,
        );
        let (tx, rx) = channel();
        let tx = tx.shaped(shaper);
        let t0 = Instant::now();
        tx.send(50_000, ()).unwrap(); // 50 ms at 1 MB/s
        rx.recv().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.045, "delivered too fast: {dt}");
        assert!(dt < 0.5, "delivered too slow: {dt}");
    }

    #[test]
    fn shaped_serialises_consecutive_messages() {
        let epoch = Instant::now();
        let shaper = Shaper::new(
            LinkModel { bytes_per_sec: 1e6, latency_s: 0.0 },
            epoch,
        );
        let (tx, rx) = channel();
        let tx = tx.shaped(shaper);
        let t0 = Instant::now();
        tx.send(30_000, 1u8).unwrap();
        tx.send(30_000, 2u8).unwrap(); // queues behind the first
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.055, "second message should queue: {dt}");
    }

    #[test]
    fn try_recv_empty() {
        let (_tx, rx) = channel::<u8>();
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn closed_channel_errors() {
        let (tx, rx) = channel::<u8>();
        drop(rx);
        assert!(tx.send(1, 0).is_err());
    }
}
