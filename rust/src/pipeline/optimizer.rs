//! Optimizers over host-side f32 parameter streams.
//!
//! Kept in Rust (not AOT HLO) deliberately: the coordinator owns model
//! state, so updates, replication, and restore are all plain buffer
//! operations, and the artifact set stays O(1) in model depth.

/// Optimizer selection + hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub enum OptimizerCfg {
    Sgd { lr: f32, momentum: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl OptimizerCfg {
    pub fn sgd(lr: f32) -> OptimizerCfg {
        OptimizerCfg::Sgd { lr, momentum: 0.9 }
    }

    pub fn adam(lr: f32) -> OptimizerCfg {
        OptimizerCfg::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    /// Optimizer state slots per parameter (Eq. 3's Mem^(OPT) factor).
    pub fn state_slots(&self) -> usize {
        match self {
            OptimizerCfg::Sgd { .. } => 1,
            OptimizerCfg::Adam { .. } => 2,
        }
    }
}

/// Per-tensor optimizer state + update rule.
#[derive(Debug, Clone)]
pub struct Optimizer {
    cfg: OptimizerCfg,
    /// first moment / momentum buffers, one per registered tensor
    m: Vec<Vec<f32>>,
    /// second moment (Adam only)
    v: Vec<Vec<f32>>,
    step: u64,
}

impl Optimizer {
    /// `sizes`: element counts of the tensors this optimizer will step.
    pub fn new(cfg: OptimizerCfg, sizes: &[usize]) -> Optimizer {
        let m = sizes.iter().map(|&n| vec![0.0; n]).collect();
        let v = match cfg {
            OptimizerCfg::Adam { .. } => sizes.iter().map(|&n| vec![0.0; n]).collect(),
            _ => Vec::new(),
        };
        Optimizer { cfg, m, v, step: 0 }
    }

    /// Apply one update step.  `params[i]` and `grads[i]` must match the
    /// registered sizes.
    pub fn step(&mut self, params: &mut [&mut [f32]], grads: &[&[f32]]) {
        assert_eq!(params.len(), self.m.len(), "optimizer tensor arity");
        assert_eq!(grads.len(), self.m.len());
        self.step += 1;
        match self.cfg {
            OptimizerCfg::Sgd { lr, momentum } => {
                for ((p, g), mbuf) in params.iter_mut().zip(grads).zip(&mut self.m) {
                    assert_eq!(p.len(), mbuf.len());
                    for i in 0..p.len() {
                        mbuf[i] = momentum * mbuf[i] + g[i];
                        p[i] -= lr * mbuf[i];
                    }
                }
            }
            OptimizerCfg::Adam { lr, beta1, beta2, eps } => {
                let t = self.step as f32;
                let bc1 = 1.0 - beta1.powf(t);
                let bc2 = 1.0 - beta2.powf(t);
                for (((p, g), mbuf), vbuf) in
                    params.iter_mut().zip(grads).zip(&mut self.m).zip(&mut self.v)
                {
                    for i in 0..p.len() {
                        mbuf[i] = beta1 * mbuf[i] + (1.0 - beta1) * g[i];
                        vbuf[i] = beta2 * vbuf[i] + (1.0 - beta2) * g[i] * g[i];
                        let mhat = mbuf[i] / bc1;
                        let vhat = vbuf[i] / bc2;
                        p[i] -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimise f(x) = (x - 3)^2 and check convergence.
    fn minimise(cfg: OptimizerCfg, steps: usize) -> f32 {
        let mut x = vec![0.0f32];
        let mut opt = Optimizer::new(cfg, &[1]);
        for _ in 0..steps {
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.step(&mut [&mut x], &[&g]);
        }
        x[0]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let x = minimise(OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 }, 200);
        assert!((x - 3.0).abs() < 1e-3, "x = {x}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let x = minimise(OptimizerCfg::adam(0.1), 500);
        assert!((x - 3.0).abs() < 1e-2, "x = {x}");
    }

    #[test]
    fn momentum_accelerates_early_progress() {
        // Compare before any overshoot can occur (3 small steps).
        let plain = minimise(OptimizerCfg::Sgd { lr: 0.05, momentum: 0.0 }, 3);
        let mom = minimise(OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 }, 3);
        assert!(
            (mom - 3.0).abs() < (plain - 3.0).abs(),
            "momentum {mom} vs plain {plain}"
        );
    }

    #[test]
    fn state_slots() {
        assert_eq!(OptimizerCfg::sgd(0.1).state_slots(), 1);
        assert_eq!(OptimizerCfg::adam(0.1).state_slots(), 2);
    }

    #[test]
    fn multi_tensor_step() {
        let mut a = vec![1.0f32; 3];
        let mut b = vec![2.0f32; 2];
        let ga = vec![1.0f32; 3];
        let gb = vec![1.0f32; 2];
        let mut opt = Optimizer::new(OptimizerCfg::Sgd { lr: 0.1, momentum: 0.0 }, &[3, 2]);
        opt.step(&mut [&mut a, &mut b], &[&ga, &gb]);
        assert!(a.iter().all(|&v| (v - 0.9).abs() < 1e-6));
        assert!(b.iter().all(|&v| (v - 1.9).abs() < 1e-6));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut opt = Optimizer::new(OptimizerCfg::sgd(0.1), &[1]);
        opt.step(&mut [], &[]);
    }
}
