//! The transport-agnostic step-execution core shared by every live
//! worker.
//!
//! A worker — in-process thread (`pipeline::worker`, `pjrt` feature)
//! or separate OS process (`asteroid-worker` over the
//! [`crate::comm::rpc`] transport) — is the same machine: execute the
//! device's `schedule::ComputeOp` script in order, blocking on the
//! inputs each scripted op needs, forwarding boundary activations
//! downstream and gradients upstream.  This module owns that machine
//! once, parameterised over
//!
//! * a [`DataPlane`] — where micro-batch tensors come from and go to
//!   (in-process channels, or framed TCP connections); and
//! * a [`StageCompute`] — what forward/backward actually *compute*
//!   (AOT-compiled PJRT executables, or the feature-independent
//!   [`ReferenceStage`] kernel the multi-process backend trains with
//!   when no accelerator binding is built in).
//!
//! Neither implementation re-derives schedule order: 1F1B, K_p windows
//! and the bounded-staleness admission window are properties of the
//! script, exactly as in the in-process engine.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::model::ModelDesc;
use crate::pipeline::optimizer::{Optimizer, OptimizerCfg};
use crate::runtime::{ParamStash, Tensor};
use crate::schedule::ComputeOp;
use crate::util::rng::Rng;

/// One micro-batch tensor moving through the pipeline, transport-
/// agnostically.
#[derive(Debug)]
pub enum DataMsg {
    /// Stage input (activations; raw data for stage 0).
    Act { micro: usize, t: Tensor },
    /// Gradient w.r.t. this stage's output.
    Grad { micro: usize, t: Tensor },
    /// Head-stage targets.
    Targets { micro: usize, t: Tensor },
}

/// Where a worker's micro-batch tensors come from and go to.  `recv`
/// blocks until the next in-flight tensor arrives (or the transport
/// dies / the round is aborted — an error ends the round).
pub trait DataPlane {
    fn recv(&mut self) -> Result<DataMsg>;
    fn send_act(&mut self, micro: usize, t: Tensor) -> Result<()>;
    fn send_grad(&mut self, micro: usize, t: Tensor) -> Result<()>;
}

/// What a stage's forward/backward actually compute.  Implementations
/// own their parameters, gradient accumulators and (under bounded
/// staleness) the weight-version stash; the script runner owns
/// ordering and transport only.
pub trait StageCompute {
    /// Forward one micro-batch.  Returns the boundary activation to
    /// ship downstream, or `None` when this stage holds the model head
    /// (the prediction is stashed for the fused loss backward).
    fn forward(&mut self, micro: usize, input: Tensor) -> Result<Option<Tensor>>;

    /// Backward one micro-batch from the downstream gradient.  Returns
    /// the input gradient for the upstream stage (`None` only when the
    /// first layer consumes it).
    fn backward(&mut self, micro: usize, grad: Tensor) -> Result<Option<Tensor>>;

    /// Fused head loss + backward for one micro-batch (head stage
    /// only): returns (loss, input gradient for upstream).
    fn backward_head(&mut self, micro: usize, targets: Tensor) -> Result<(f64, Option<Tensor>)>;

    /// Deferred weight-gradient slot of a split backward (zero-bubble
    /// policies).  Order-validated bookkeeping unless the kernel
    /// actually defers weight gradients.
    fn backward_weights(&mut self, micro: usize) -> Result<()>;
}

/// Static description of one worker — the schedule slice plus the
/// training knobs both engines consume (moved here from the pjrt-gated
/// worker so the multi-process backend shares one definition).
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    pub stage: usize,
    /// Layer range [lo, hi) into the model's layer list.
    pub layers: (usize, usize),
    pub slot: usize,
    /// This device's ordered FP/BP work for one HPP-Round, from
    /// `Schedule::compute_script(stage, slot)` — the single source of
    /// 1F1B/K_p ordering.
    pub script: Vec<ComputeOp>,
    /// Bounded-staleness weight-stash ring depth (the schedule's
    /// effective admission window, K_p + sigma).  0 = synchronous
    /// policy: gradients accumulate across the round and no stash
    /// exists.
    pub stash_slots: usize,
    pub num_micro: usize,
    pub is_first: bool,
    pub is_last: bool,
    pub seed: u64,
    pub opt: OptimizerCfg,
    /// Warm-start parameters by global layer index (fault-tolerance
    /// restore / checkpoint resume); layers not present use fresh init.
    pub initial_params: Option<Arc<BTreeMap<usize, Vec<Tensor>>>>,
}

/// Execute one HPP-Round of `script` against `compute`, pumping
/// tensors through `dp`.  Returns the round's loss sum (head stage
/// only; 0 elsewhere).
///
/// The runner buffers out-of-order arrivals per kind and blocks before
/// each op until its input is present — the script order already
/// respects 1F1B and the K_p/staleness window, so this cannot deadlock
/// for any schedule that passed `Schedule::validate`.
pub fn run_script_round(
    script: &[ComputeOp],
    is_first: bool,
    is_last: bool,
    compute: &mut dyn StageCompute,
    dp: &mut dyn DataPlane,
) -> Result<f64> {
    let mut acts: BTreeMap<usize, Tensor> = BTreeMap::new();
    let mut grads_in: BTreeMap<usize, Tensor> = BTreeMap::new();
    let mut targets: BTreeMap<usize, Tensor> = BTreeMap::new();
    let mut loss_sum = 0.0f64;

    let mut pump = |acts: &mut BTreeMap<usize, Tensor>,
                    grads_in: &mut BTreeMap<usize, Tensor>,
                    targets: &mut BTreeMap<usize, Tensor>,
                    dp: &mut dyn DataPlane|
     -> Result<()> {
        match dp.recv()? {
            DataMsg::Act { micro, t } => {
                acts.insert(micro, t);
            }
            DataMsg::Grad { micro, t } => {
                grads_in.insert(micro, t);
            }
            DataMsg::Targets { micro, t } => {
                targets.insert(micro, t);
            }
        }
        Ok(())
    };

    for op in script {
        match *op {
            ComputeOp::Fwd(m) => {
                while !acts.contains_key(&m) {
                    pump(&mut acts, &mut grads_in, &mut targets, dp)?;
                }
                let x = acts.remove(&m).unwrap();
                if let Some(out) = compute.forward(m, x)? {
                    dp.send_act(m, out)?;
                }
            }
            ComputeOp::Bwd(m) => {
                let gx = if is_last {
                    while !targets.contains_key(&m) {
                        pump(&mut acts, &mut grads_in, &mut targets, dp)?;
                    }
                    let tgt = targets.remove(&m).unwrap();
                    let (loss, gx) = compute.backward_head(m, tgt)?;
                    loss_sum += loss;
                    gx
                } else {
                    while !grads_in.contains_key(&m) {
                        pump(&mut acts, &mut grads_in, &mut targets, dp)?;
                    }
                    let g = grads_in.remove(&m).unwrap();
                    compute.backward(m, g)?
                };
                if !is_first {
                    let t = gx.context("non-first stage must produce an input gradient")?;
                    dp.send_grad(m, t)?;
                }
            }
            ComputeOp::BwdW(m) => compute.backward_weights(m)?,
        }
    }
    Ok(loss_sum)
}

// =====================================================================
// Reference compute kernel (feature-independent)
// =====================================================================

/// Dimensions of one reference layer, derived from the planned model's
/// layer table: the tensors this kernel moves have exactly the byte
/// sizes the planner and simulator priced (Eq. 3 / the link model),
/// while the arithmetic is a cheap learnable surrogate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RefLayerSpec {
    /// Global model layer index (checkpoint / warm-start key).
    pub layer: usize,
    /// Input elements per sample.
    pub in_elems: usize,
    /// Output elements per sample.
    pub out_elems: usize,
    /// True for the model's final layer: its output is the prediction
    /// the MSE head scores against the targets.
    pub head: bool,
}

/// Reference layer dimensions for the model slice [lo, hi) — the
/// element counts come straight from the model's activation byte
/// table, so inter-stage transfers carry honestly-sized tensors.
pub fn reference_layers(model: &ModelDesc, lo: usize, hi: usize) -> Vec<RefLayerSpec> {
    let f32_bytes = 4;
    (lo..hi)
        .map(|k| {
            let in_bytes = if k == 0 { model.input_bytes } else { model.boundary_bytes(k) };
            RefLayerSpec {
                layer: k,
                in_elems: (in_bytes as usize / f32_bytes).max(1),
                out_elems: (model.layers[k].out_bytes as usize / f32_bytes).max(1),
                head: k + 1 == model.num_layers(),
            }
        })
        .collect()
}

/// Per-sample input element count of the whole model (what the driver
/// feeds stage 0).
pub fn reference_input_elems(model: &ModelDesc) -> usize {
    (model.input_bytes as usize / 4).max(1)
}

/// Per-sample target element count (the head layer's output width).
pub fn reference_target_elems(model: &ModelDesc) -> usize {
    (model.layers[model.num_layers() - 1].out_bytes as usize / 4).max(1)
}

struct RefLayer {
    spec: RefLayerSpec,
    scale: Vec<f32>,
    bias: Vec<f32>,
    g_scale: Vec<f32>,
    g_bias: Vec<f32>,
}

/// Per-micro forward trace of one layer (rematerialisation-free BP).
struct LayerTrace {
    input: Vec<f32>,
    output: Vec<f32>,
}

type RefSnapshot = Vec<(Vec<f32>, Vec<f32>)>;

/// The feature-independent stage kernel the multi-process backend
/// executes: per layer `y[j] = tanh(scale[j] * x[j mod d_in] + bias[j])`
/// with exact analytic gradients, seeded layer-deterministic init
/// (replicas of a layer agree), per-micro bounded-staleness updates
/// against [`ParamStash`]-pinned snapshots, and an MSE head.
///
/// This is a *surrogate* for the AOT-compiled model math (DESIGN.md
/// §Substitutions): tensor shapes, transfer bytes, schedule semantics,
/// weight-version behaviour and loss learnability are real; the
/// numerics are not the paper's models.  Build with `--features pjrt`
/// and a real binding for those.
pub struct ReferenceStage {
    layers: Vec<RefLayer>,
    microbatch: usize,
    num_micro: usize,
    stash_slots: usize,
    opt: Optimizer,
    version: u64,
    stash: ParamStash<RefSnapshot>,
    /// Per-micro traces of every layer, released at the micro's Bwd.
    saved: BTreeMap<usize, Vec<LayerTrace>>,
    bwd_done: std::collections::BTreeSet<usize>,
}

impl ReferenceStage {
    /// Seeded init: layer k's parameters depend on (seed, k) only, so
    /// replicas agree and a re-spawned worker reproduces them exactly.
    pub fn new(
        specs: &[RefLayerSpec],
        seed: u64,
        opt: OptimizerCfg,
        stash_slots: usize,
        microbatch: usize,
        num_micro: usize,
    ) -> Result<ReferenceStage> {
        anyhow::ensure!(!specs.is_empty(), "reference stage has no layers");
        anyhow::ensure!(num_micro > 0 && microbatch > 0, "empty round");
        let mut layers = Vec::with_capacity(specs.len());
        for s in specs {
            let mut rng = Rng::new(seed ^ (s.layer as u64).wrapping_mul(0x9E37_79B9));
            let mut scale = vec![0.0f32; s.out_elems];
            rng.fill_normal(&mut scale, 0.4);
            for v in &mut scale {
                *v += 0.6; // centred near identity-ish gain, sign-diverse
            }
            layers.push(RefLayer {
                spec: *s,
                scale,
                bias: vec![0.0; s.out_elems],
                g_scale: vec![0.0; s.out_elems],
                g_bias: vec![0.0; s.out_elems],
            });
        }
        let sizes: Vec<usize> = layers
            .iter()
            .flat_map(|l| [l.scale.len(), l.bias.len()])
            .collect();
        Ok(ReferenceStage {
            layers,
            microbatch,
            num_micro,
            stash_slots,
            opt: Optimizer::new(opt, &sizes),
            version: 0,
            stash: ParamStash::new(stash_slots.max(1)),
            saved: BTreeMap::new(),
            bwd_done: Default::default(),
        })
    }

    fn async_updates(&self) -> bool {
        self.stash_slots > 0
    }

    /// Expected stage input width per sample.
    pub fn in_elems(&self) -> usize {
        self.layers[0].spec.in_elems
    }

    /// Forward one micro through every layer with `weights`, recording
    /// traces.  Returns the last layer's output batch.
    fn forward_with(
        &mut self,
        micro: usize,
        x: &[f32],
        weights: Option<&RefSnapshot>,
    ) -> Result<Vec<f32>> {
        let b = self.microbatch;
        anyhow::ensure!(
            x.len() == b * self.in_elems(),
            "stage input for micro {micro}: {} elements, expected {} ({}x{})",
            x.len(),
            b * self.in_elems(),
            b,
            self.in_elems()
        );
        let mut traces = Vec::with_capacity(self.layers.len());
        let mut cur = x.to_vec();
        for (k, l) in self.layers.iter().enumerate() {
            let (scale, bias) = match weights {
                Some(w) => (&w[k].0, &w[k].1),
                None => (&l.scale, &l.bias),
            };
            let d_in = l.spec.in_elems;
            let d_out = l.spec.out_elems;
            anyhow::ensure!(
                cur.len() == b * d_in,
                "layer {} input width {} != {}",
                l.spec.layer,
                cur.len(),
                b * d_in
            );
            let mut out = vec![0.0f32; b * d_out];
            for s in 0..b {
                let xin = &cur[s * d_in..(s + 1) * d_in];
                let yout = &mut out[s * d_out..(s + 1) * d_out];
                for j in 0..d_out {
                    yout[j] = (scale[j] * xin[j % d_in] + bias[j]).tanh();
                }
            }
            traces.push(LayerTrace { input: cur, output: out.clone() });
            cur = out;
        }
        self.saved.insert(micro, traces);
        Ok(cur)
    }

    /// Backward one micro from the loss gradient at the stage output,
    /// accumulating parameter gradients against `weights` and returning
    /// the input gradient.
    fn backward_with(
        &mut self,
        micro: usize,
        mut g: Vec<f32>,
        weights: Option<&RefSnapshot>,
    ) -> Result<Vec<f32>> {
        let traces = self
            .saved
            .remove(&micro)
            .with_context(|| format!("no stashed forward trace for micro {micro}"))?;
        let b = self.microbatch;
        for k in (0..self.layers.len()).rev() {
            let d_in = self.layers[k].spec.in_elems;
            let d_out = self.layers[k].spec.out_elems;
            let tr = &traces[k];
            anyhow::ensure!(g.len() == b * d_out, "gradient width mismatch at layer {k}");
            let scale = match weights {
                Some(w) => w[k].0.clone(),
                None => self.layers[k].scale.clone(),
            };
            let mut gx = vec![0.0f32; b * d_in];
            {
                let l = &mut self.layers[k];
                for s in 0..b {
                    let xin = &tr.input[s * d_in..(s + 1) * d_in];
                    let yout = &tr.output[s * d_out..(s + 1) * d_out];
                    let gy = &g[s * d_out..(s + 1) * d_out];
                    let gxi = &mut gx[s * d_in..(s + 1) * d_in];
                    for j in 0..d_out {
                        let dz = gy[j] * (1.0 - yout[j] * yout[j]);
                        l.g_scale[j] += dz * xin[j % d_in];
                        l.g_bias[j] += dz;
                        gxi[j % d_in] += dz * scale[j];
                    }
                }
            }
            g = gx;
        }
        self.bwd_done.insert(micro);
        Ok(g)
    }

    /// Release the weight snapshot a backward must run against
    /// (bounded staleness: the version its forward pinned), mirroring
    /// the pjrt worker's `take_bwd_lits`.  `None` for synchronous
    /// policies — the round-constant live weights apply.
    fn take_pinned(&mut self, micro: usize) -> Result<Option<Arc<RefSnapshot>>> {
        if !self.async_updates() {
            return Ok(None);
        }
        let (_, snap) = self
            .stash
            .take(micro)
            .with_context(|| format!("no stashed weights for micro {micro}"))?;
        Ok(Some(snap))
    }

    /// Post-backward bookkeeping shared by both backward paths
    /// (mirrors the pjrt worker's `post_backward`): a bounded-
    /// staleness stage applies this micro's gradient immediately,
    /// advancing the version the next forward reads; synchronous
    /// stages just keep accumulating.
    fn finish_backward(&mut self) -> Result<()> {
        if self.async_updates() {
            self.apply_scaled(1.0 / self.num_micro as f32);
            self.zero_grads();
            self.version += 1;
        }
        Ok(())
    }

    fn apply_scaled(&mut self, scale: f32) {
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(2 * self.layers.len());
        for l in &self.layers {
            grads.push(l.g_scale.iter().map(|g| g * scale).collect());
            grads.push(l.g_bias.iter().map(|g| g * scale).collect());
        }
        let mut p_refs: Vec<&mut [f32]> = Vec::with_capacity(grads.len());
        for l in &mut self.layers {
            p_refs.push(&mut l.scale);
            p_refs.push(&mut l.bias);
        }
        let g_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        self.opt.step(&mut p_refs, &g_refs);
    }

    fn zero_grads(&mut self) {
        for l in &mut self.layers {
            l.g_scale.iter_mut().for_each(|v| *v = 0.0);
            l.g_bias.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    /// Round-end update for a synchronous policy running without a
    /// replica group: one optimizer step over the 1/M-scaled round
    /// gradient (bounded-staleness stages already updated per micro).
    pub fn end_round_local(&mut self) -> Result<()> {
        self.bwd_done.clear();
        if self.async_updates() {
            return Ok(());
        }
        self.apply_scaled(1.0 / self.num_micro as f32);
        self.zero_grads();
        Ok(())
    }

    /// Flattened gradient accumulators (replicated-stage round sync,
    /// synchronous policies).
    pub fn flat_grads(&self) -> Vec<f32> {
        self.layers
            .iter()
            .flat_map(|l| l.g_scale.iter().chain(l.g_bias.iter()).copied())
            .collect()
    }

    /// Flattened live parameters (replicated-stage parameter
    /// averaging, bounded-staleness policies).
    pub fn flat_params(&self) -> Vec<f32> {
        self.layers
            .iter()
            .flat_map(|l| l.scale.iter().chain(l.bias.iter()).copied())
            .collect()
    }

    /// Apply the group-summed round gradient (synchronous policies):
    /// one step over the 1/M-scaled sum, as the in-process AllReduce
    /// path does.
    pub fn apply_round_gradients(&mut self, summed: &[f32]) -> Result<()> {
        self.bwd_done.clear();
        let expect: usize = self.layers.iter().map(|l| 2 * l.scale.len()).sum();
        anyhow::ensure!(summed.len() == expect, "round-sync gradient length mismatch");
        let mut off = 0;
        for l in &mut self.layers {
            let n = l.g_scale.len();
            l.g_scale.copy_from_slice(&summed[off..off + n]);
            off += n;
            l.g_bias.copy_from_slice(&summed[off..off + n]);
            off += n;
        }
        self.apply_scaled(1.0 / self.num_micro as f32);
        self.zero_grads();
        Ok(())
    }

    /// Overwrite the live parameters (replica parameter averaging);
    /// invalidates the stash dedup anchor — the next forward must not
    /// alias a pre-average snapshot.
    pub fn set_flat_params(&mut self, flat: &[f32]) -> Result<()> {
        self.bwd_done.clear();
        let expect: usize = self.layers.iter().map(|l| 2 * l.scale.len()).sum();
        anyhow::ensure!(flat.len() == expect, "round-sync parameter length mismatch");
        let mut off = 0;
        for l in &mut self.layers {
            let n = l.scale.len();
            l.scale.copy_from_slice(&flat[off..off + n]);
            off += n;
            l.bias.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        self.stash.invalidate_last();
        Ok(())
    }

    /// Current parameters by global layer index (checkpoint stream).
    pub fn layer_states(&self) -> Vec<(usize, Vec<f32>, Vec<f32>)> {
        self.layers
            .iter()
            .map(|l| (l.spec.layer, l.scale.clone(), l.bias.clone()))
            .collect()
    }

    /// Warm-start from checkpointed layer states (ignores layers
    /// outside this stage's range).
    pub fn load_layer_states(
        &mut self,
        states: &[(usize, Vec<f32>, Vec<f32>)],
    ) -> Result<()> {
        for (layer, scale, bias) in states {
            if let Some(l) = self.layers.iter_mut().find(|l| l.spec.layer == *layer) {
                anyhow::ensure!(
                    scale.len() == l.scale.len() && bias.len() == l.bias.len(),
                    "warm-start arity for layer {layer}"
                );
                l.scale.copy_from_slice(scale);
                l.bias.copy_from_slice(bias);
            }
        }
        self.stash.invalidate_last();
        Ok(())
    }

    /// Drop all in-flight round state (fault-recovery abort): stashed
    /// traces, pinned weight versions and accumulated gradients.
    pub fn abort_round(&mut self) {
        self.saved.clear();
        self.bwd_done.clear();
        self.stash = ParamStash::new(self.stash_slots.max(1));
        self.zero_grads();
    }
}

impl StageCompute for ReferenceStage {
    fn forward(&mut self, micro: usize, input: Tensor) -> Result<Option<Tensor>> {
        if self.async_updates() {
            // Pin the version this forward reads; the live weights ARE
            // that version right now, so the forward itself runs on
            // them and only the backward needs the pinned copy.  The
            // snapshot closure stays lazy — `ParamStash::record` skips
            // it when the version is unchanged since the last record
            // (warm-up admits K_p + sigma forwards of one version), so
            // the parameter deep-copy happens once per version, not
            // once per forward.
            let ReferenceStage { stash, layers, version, .. } = self;
            stash.record(micro, *version, || {
                Arc::new(layers.iter().map(|l| (l.scale.clone(), l.bias.clone())).collect())
            })?;
        }
        let x = input.as_f32().context("reference stage expects f32 input")?.to_vec();
        let out = self.forward_with(micro, &x, None)?;
        let head = self.layers.last().unwrap().spec.head;
        if head {
            // Prediction stashed in the trace; scored at the Bwd slot.
            Ok(None)
        } else {
            let d_out = self.layers.last().unwrap().spec.out_elems;
            Ok(Some(Tensor::from_f32(&[self.microbatch, d_out], out)))
        }
    }

    fn backward(&mut self, micro: usize, grad: Tensor) -> Result<Option<Tensor>> {
        let snap = self.take_pinned(micro)?;
        let g = grad.as_f32().context("gradient must be f32")?.to_vec();
        let gx = self.backward_with(micro, g, snap.as_deref())?;
        self.finish_backward()?;
        let d_in = self.in_elems();
        Ok(Some(Tensor::from_f32(&[self.microbatch, d_in], gx)))
    }

    fn backward_head(&mut self, micro: usize, targets: Tensor) -> Result<(f64, Option<Tensor>)> {
        let snap = self.take_pinned(micro)?;
        let head = self.layers.last().unwrap().spec;
        anyhow::ensure!(head.head, "backward_head on a stage without the model head");
        let pred = {
            let traces = self
                .saved
                .get(&micro)
                .with_context(|| format!("no forward trace for micro {micro}"))?;
            traces.last().unwrap().output.clone()
        };
        let tgt = targets.as_f32().context("targets must be f32")?;
        anyhow::ensure!(
            tgt.len() == pred.len(),
            "targets: {} elements, prediction has {}",
            tgt.len(),
            pred.len()
        );
        // MSE over (batch x head width); gradient 2(p - t)/n.
        let n = pred.len() as f64;
        let mut loss = 0.0f64;
        let mut g = vec![0.0f32; pred.len()];
        for (i, (&p, &t)) in pred.iter().zip(tgt).enumerate() {
            let d = (p - t) as f64;
            loss += d * d;
            g[i] = (2.0 * d / n) as f32;
        }
        loss /= n;
        let gx = self.backward_with(micro, g, snap.as_deref())?;
        self.finish_backward()?;
        let d_in = self.in_elems();
        Ok((loss, Some(Tensor::from_f32(&[self.microbatch, d_in], gx))))
    }

    fn backward_weights(&mut self, micro: usize) -> Result<()> {
        // The reference backward computes input- and weight-gradients
        // fused (like the AOT executables), so the scheduled BwdW slot
        // only validates order — same contract as the pjrt worker.
        anyhow::ensure!(
            self.bwd_done.contains(&micro),
            "unsupported op order: BwdW({micro}) before its Bwd"
        );
        Ok(())
    }
}

// =====================================================================
// Reference task (driver-side synthetic data)
// =====================================================================

/// Deterministic synthetic task for the reference kernel: inputs are
/// seeded noise, targets follow a fixed per-position affine map of the
/// sample mean squashed through tanh — learnable by the reference
/// stack, reproducible per (seed, round, micro) so a fault-recovery
/// replay regenerates byte-identical micro-batches.
pub struct RefTask {
    in_elems: usize,
    target_elems: usize,
    microbatch: usize,
    seed: u64,
    /// Fixed target-map coefficients (never trained).
    map_a: Vec<f32>,
    map_b: Vec<f32>,
}

impl RefTask {
    pub fn new(model: &ModelDesc, microbatch: usize, seed: u64) -> RefTask {
        let target_elems = reference_target_elems(model);
        let mut rng = Rng::new(seed ^ 0xA57E_401D);
        let mut map_a = vec![0.0f32; target_elems];
        let mut map_b = vec![0.0f32; target_elems];
        rng.fill_normal(&mut map_a, 1.0);
        rng.fill_normal(&mut map_b, 0.3);
        RefTask {
            in_elems: reference_input_elems(model),
            target_elems,
            microbatch,
            seed,
            map_a,
            map_b,
        }
    }

    /// The (input, target) pair of `micro` in `round` — a pure
    /// function of (seed, round, micro).
    pub fn microbatch(&self, round: usize, micro: usize) -> (Tensor, Tensor) {
        let tag = (round as u64) << 32 | micro as u64;
        let mut rng = Rng::new(self.seed ^ tag.wrapping_mul(0xD134_2543_DE82_EF95));
        let b = self.microbatch;
        let mut x = vec![0.0f32; b * self.in_elems];
        rng.fill_normal(&mut x, 1.0);
        let mut t = vec![0.0f32; b * self.target_elems];
        for s in 0..b {
            let xs = &x[s * self.in_elems..(s + 1) * self.in_elems];
            let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
            let ts = &mut t[s * self.target_elems..(s + 1) * self.target_elems];
            for (j, v) in ts.iter_mut().enumerate() {
                *v = (self.map_a[j] * mean + self.map_b[j]).tanh();
            }
        }
        (
            Tensor::from_f32(&[b, self.in_elems], x),
            Tensor::from_f32(&[b, self.target_elems], t),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::schedule::{OneFOneBKp, SchedulePolicy};
    use std::collections::VecDeque;

    /// Loopback data plane for single-stage tests: sends loop back
    /// into the receive queue of a mailbox.
    struct Mailbox {
        inbox: VecDeque<DataMsg>,
        sent_acts: Vec<(usize, Tensor)>,
        sent_grads: Vec<(usize, Tensor)>,
    }

    impl DataPlane for Mailbox {
        fn recv(&mut self) -> Result<DataMsg> {
            self.inbox.pop_front().context("mailbox empty")
        }

        fn send_act(&mut self, micro: usize, t: Tensor) -> Result<()> {
            self.sent_acts.push((micro, t));
            Ok(())
        }

        fn send_grad(&mut self, micro: usize, t: Tensor) -> Result<()> {
            self.sent_grads.push((micro, t));
            Ok(())
        }
    }

    fn tiny_model() -> ModelDesc {
        use crate::model::Layer;
        ModelDesc::new(
            "tiny",
            vec![
                Layer::new("a", 100.0, 64, 32),
                Layer::new("b", 100.0, 64, 24),
                Layer::new("head", 100.0, 64, 16),
            ],
            40,
        )
    }

    #[test]
    fn reference_layers_match_model_bytes() {
        let model = tiny_model();
        let specs = reference_layers(&model, 0, 3);
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].in_elems, 10); // input_bytes 40 / 4
        assert_eq!(specs[0].out_elems, 8); // 32 / 4
        assert_eq!(specs[1].in_elems, 8);
        assert_eq!(specs[2].out_elems, 4);
        assert!(specs[2].head && !specs[0].head);
        assert_eq!(reference_input_elems(&model), 10);
        assert_eq!(reference_target_elems(&model), 4);
    }

    #[test]
    fn single_stage_round_learns() {
        // One stage holding the whole tiny model: the MSE loss over the
        // deterministic task must fall over a few rounds.
        let model = tiny_model();
        let specs = reference_layers(&model, 0, 3);
        let b = 4;
        let m_total = 2;
        let mut stage = ReferenceStage::new(
            &specs,
            7,
            OptimizerCfg::sgd(0.1),
            0,
            b,
            m_total,
        )
        .unwrap();
        let task = RefTask::new(&model, b, 7);
        let script = OneFOneBKp.compute_order(&[0, 1], 1);
        let mut losses = Vec::new();
        for round in 0..12 {
            let mut dp = Mailbox {
                inbox: VecDeque::new(),
                sent_acts: Vec::new(),
                sent_grads: Vec::new(),
            };
            for m in 0..m_total {
                let (x, t) = task.microbatch(round, m);
                dp.inbox.push_back(DataMsg::Act { micro: m, t: x });
                dp.inbox.push_back(DataMsg::Targets { micro: m, t });
            }
            let loss = run_script_round(&script, true, true, &mut stage, &mut dp).unwrap();
            stage.end_round_local().unwrap();
            assert!(dp.sent_acts.is_empty(), "head stage must not forward");
            assert!(dp.sent_grads.is_empty(), "first stage must not send grads");
            losses.push(loss / m_total as f64);
        }
        assert!(
            *losses.last().unwrap() < losses[0] * 0.95,
            "loss did not fall: {losses:?}"
        );
    }

    #[test]
    fn two_stage_chain_matches_boundary_shapes() {
        // Stage 0 forwards an honestly-shaped boundary tensor; feeding
        // it into stage 1 and returning the gradient closes the loop.
        let model = tiny_model();
        let b = 2;
        let mut s0 =
            ReferenceStage::new(&reference_layers(&model, 0, 1), 1, OptimizerCfg::sgd(0.1), 0, b, 1)
                .unwrap();
        let mut s1 =
            ReferenceStage::new(&reference_layers(&model, 1, 3), 1, OptimizerCfg::sgd(0.1), 0, b, 1)
                .unwrap();
        let task = RefTask::new(&model, b, 1);
        let (x, t) = task.microbatch(0, 0);

        let act = s0.forward(0, x).unwrap().expect("stage 0 forwards");
        assert_eq!(act.shape, vec![b, 8]); // 32 bytes / 4 per sample
        assert!(s1.forward(0, act).unwrap().is_none(), "head stage stashes");
        let (loss, gx) = s1.backward_head(0, t).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let gx = gx.unwrap();
        assert_eq!(gx.shape, vec![b, 8]);
        let g0 = s0.backward(0, gx).unwrap().unwrap();
        assert_eq!(g0.shape, vec![b, 10]);
        s0.end_round_local().unwrap();
        s1.end_round_local().unwrap();
    }

    #[test]
    fn async_updates_pin_forward_versions() {
        // Under a bounded-staleness script the backward must run
        // against the snapshot its forward read even after intervening
        // per-micro updates — take() returns the pinned version.
        let model = tiny_model();
        let specs = reference_layers(&model, 0, 3);
        let b = 2;
        let mut stage =
            ReferenceStage::new(&specs, 3, OptimizerCfg::sgd(0.3), 3, b, 3).unwrap();
        let task = RefTask::new(&model, b, 3);
        // Admit three forwards (versions 0,0,0), then three backwards:
        // each advances the version; each must still find its pin.
        for m in 0..3 {
            let (x, _) = task.microbatch(0, m);
            assert!(stage.forward(m, x).unwrap().is_none());
        }
        assert_eq!(stage.stash.len(), 3);
        for m in 0..3 {
            let (_, t) = task.microbatch(0, m);
            let (loss, _) = stage.backward_head(m, t).unwrap();
            assert!(loss.is_finite());
        }
        assert_eq!(stage.version, 3, "one update per backward");
        assert!(stage.stash.is_empty());
        stage.end_round_local().unwrap();
        // Overflowing the ring is a scheduling bug, reported as such.
        let mut tight =
            ReferenceStage::new(&specs, 3, OptimizerCfg::sgd(0.3), 1, b, 3).unwrap();
        let (x0, _) = task.microbatch(0, 0);
        let (x1, _) = task.microbatch(0, 1);
        assert!(tight.forward(0, x0).unwrap().is_none());
        assert!(tight.forward(1, x1).is_err(), "stash ring must reject overrun");
    }

    #[test]
    fn checkpoint_roundtrip_and_abort() {
        let model = tiny_model();
        let specs = reference_layers(&model, 0, 3);
        let mut a = ReferenceStage::new(&specs, 5, OptimizerCfg::sgd(0.1), 0, 2, 1).unwrap();
        let mut b = ReferenceStage::new(&specs, 99, OptimizerCfg::sgd(0.1), 0, 2, 1).unwrap();
        let states = a.layer_states();
        assert_ne!(b.layer_states(), states, "different seeds differ");
        b.load_layer_states(&states).unwrap();
        assert_eq!(b.layer_states(), states);
        // Abort clears in-flight traces so a restarted round is clean.
        let task = RefTask::new(&model, 2, 5);
        let (x, _) = task.microbatch(0, 0);
        let _ = a.forward(0, x).unwrap();
        assert!(!a.saved.is_empty());
        a.abort_round();
        assert!(a.saved.is_empty());
    }

    #[test]
    fn ref_task_is_deterministic() {
        let model = tiny_model();
        let t1 = RefTask::new(&model, 4, 11);
        let t2 = RefTask::new(&model, 4, 11);
        let (a_in, a_t) = t1.microbatch(3, 1);
        let (b_in, b_t) = t2.microbatch(3, 1);
        assert_eq!(a_in, b_in);
        assert_eq!(a_t, b_t);
        let (c_in, _) = t1.microbatch(4, 1);
        assert_ne!(a_in, c_in, "rounds must differ");
    }

    #[test]
    fn reference_layers_for_zoo_models() {
        // Every zoo model yields a usable reference chain.
        for m in [zoo::mobilenet_v2(), zoo::efficientnet_b1(), zoo::bert_small()] {
            let specs = reference_layers(&m, 0, m.num_layers());
            assert_eq!(specs.len(), m.num_layers());
            assert!(specs.iter().all(|s| s.in_elems > 0 && s.out_elems > 0));
            assert!(specs.last().unwrap().head);
        }
    }

    /// `WorkerSpec` stays constructible featureless (it moved here from
    /// the pjrt-gated worker).
    #[test]
    fn worker_spec_is_feature_independent() {
        use crate::planner::plan::Plan;
        let plan = Plan {
            stages: vec![crate::planner::plan::Stage {
                layers: (0, 2),
                devices: vec![0],
                alloc: vec![4],
                kp: 1,
            }],
            microbatch: 4,
            num_micro: 2,
        };
        let sched = crate::schedule::Schedule::for_runtime(&plan, &OneFOneBKp);
        let spec = WorkerSpec {
            stage: 0,
            layers: (0, 2),
            slot: 0,
            script: sched.compute_script(0, 0),
            stash_slots: 0,
            num_micro: 2,
            is_first: true,
            is_last: true,
            seed: 1,
            opt: OptimizerCfg::sgd(0.1),
            initial_params: None,
        };
        assert_eq!(spec.script.len(), 4);
    }
}
