//! Training-loop orchestration: spawn one Asteroid Worker per
//! (stage, replica), wire shaped channels per the plan's device
//! topology, feed micro-batches, collect per-round losses.
//!
//! This is the L3 hot path: Python is never involved — all compute runs
//! through the AOT PJRT executables inside the workers.  The engine
//! itself only exists under the `pjrt` feature; without it, [`train`]
//! is a stub that reports the missing feature (the session layer's
//! `SimBackend` covers every featureless use case).

#[cfg(not(feature = "pjrt"))]
use std::path::Path;

#[cfg(not(feature = "pjrt"))]
use anyhow::Result;

use crate::codec::CodecSpec;
use crate::config::ClusterSpec;
#[cfg(not(feature = "pjrt"))]
use crate::data::DataSource;
use crate::pipeline::optimizer::OptimizerCfg;
#[cfg(not(feature = "pjrt"))]
use crate::planner::plan::Plan;
use crate::schedule::{SchedulePolicy, DEFAULT_POLICY};

/// Training options for the real pipeline engine.
#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub steps: usize,
    pub opt: OptimizerCfg,
    pub seed: u64,
    /// When set, shape every inter-worker link with the cluster's D2D
    /// bandwidth matrix (edge-network emulation); None = full speed.
    pub emulate: Option<ClusterSpec>,
    /// Print a progress line every n steps (0 = silent).
    pub log_every: usize,
    /// Warm-start parameters by global layer index (fault-tolerance
    /// restore or checkpoint resume).
    pub initial_params: Option<std::sync::Arc<std::collections::BTreeMap<usize, Vec<crate::runtime::Tensor>>>>,
    /// Round schedule policy the workers execute (the session threads
    /// its `.schedule(..)` choice here; the default is only for direct
    /// `train` callers).
    pub policy: &'static dyn SchedulePolicy,
    /// Wire codec for inter-stage traffic: each worker transcodes its
    /// outbound activations/gradients (encode-then-decode) so the next
    /// stage computes on exactly the wire's numerics, and the link
    /// shaper charges the compressed byte count.
    pub codec: CodecSpec,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 20,
            opt: OptimizerCfg::sgd(0.05),
            seed: 42,
            emulate: None,
            log_every: 5,
            initial_params: None,
            policy: DEFAULT_POLICY,
            codec: CodecSpec::default(),
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainStats {
    /// Mean loss per round.
    pub losses: Vec<f64>,
    /// Wall-clock per round (seconds).
    pub round_secs: Vec<f64>,
    /// Mean samples/second over the run.
    pub samples_per_sec: f64,
    /// Final parameter values by global layer index — the coordinator-
    /// side checkpoint (fault-tolerance restore source).
    pub final_params: std::collections::BTreeMap<usize, Vec<crate::runtime::Tensor>>,
}

/// Stub without the `pjrt` feature: live execution is unavailable, and
/// says so instead of deadlocking or linking against nothing.
#[cfg(not(feature = "pjrt"))]
pub fn train(
    _artifacts_dir: &Path,
    _model_name: &str,
    _plan: &Plan,
    _opts: &TrainOpts,
    _data: &mut dyn DataSource,
) -> Result<TrainStats> {
    anyhow::bail!(
        "live pipeline execution requires the `pjrt` cargo feature \
         (cargo build --release --features pjrt, with a real xla binding — \
         see rust/xla/README.md); use session::SimBackend for schedule pricing"
    )
}

#[cfg(feature = "pjrt")]
pub use live::train;

#[cfg(feature = "pjrt")]
mod live {
    use std::path::Path;
    use std::sync::mpsc;
    use std::time::Instant;

    use anyhow::{bail, Context, Result};

    use super::{TrainOpts, TrainStats};
    use crate::data::DataSource;
    use crate::model::from_manifest::Manifest;
    use crate::pipeline::channel::{channel, LinkModel, Rx, Shaper, Tx};
    use crate::pipeline::collective::GroupComm;
    use crate::pipeline::worker::{run_worker, Msg, Report, WorkerSpec};
    use crate::planner::plan::Plan;
    use crate::schedule::Schedule;

    /// Train `model_name` under `plan` for `opts.steps` HPP-Rounds.
    pub fn train(
        artifacts_dir: &Path,
        model_name: &str,
        plan: &Plan,
        opts: &TrainOpts,
        data: &mut dyn DataSource,
    ) -> Result<TrainStats> {
        let manifest = Manifest::load(artifacts_dir)?;
        let model = manifest.model(model_name)?.clone();
        if plan.microbatch != model.microbatch {
            bail!(
                "plan micro-batch {} != compiled micro-batch {} (re-run aot.py)",
                plan.microbatch,
                model.microbatch
            );
        }
        let n_stages = plan.stages.len();
        let m_total = plan.num_micro;

        // ---- the round schedule: one IR, every worker executes its slice --
        // Round-robin sharding (micro m -> slot m mod g) under the run's
        // schedule policy; each worker receives its device's compute script
        // and never re-derives the order.
        let sched = Schedule::for_runtime(plan, opts.policy);
        // Hard check: an invalid schedule would deadlock the worker
        // threads silently; validation is microseconds next to a round.
        sched.validate().context("invalid round schedule")?;

        // ---- channels: one inbox per worker -------------------------------
        let mut txs: Vec<Vec<Tx<Msg>>> = Vec::new(); // [stage][slot]
        let mut rxs: Vec<Vec<Option<Rx<Msg>>>> = Vec::new();
        for stage in &plan.stages {
            let mut st = Vec::new();
            let mut sr = Vec::new();
            for _ in &stage.devices {
                let (tx, rx) = channel();
                st.push(tx);
                sr.push(Some(rx));
            }
            txs.push(st);
            rxs.push(sr);
        }

        // ---- link shaping ---------------------------------------------------
        let epoch = Instant::now();
        let shaped = |from_dev: usize, to_dev: usize, tx: &Tx<Msg>| -> Tx<Msg> {
            match &opts.emulate {
                None => tx.clone(),
                Some(cluster) => {
                    let bw = cluster.bandwidth[from_dev][to_dev];
                    tx.shaped(Shaper::new(
                        LinkModel { bytes_per_sec: bw, latency_s: cluster.latency_s },
                        epoch,
                    ))
                }
            }
        };

        // ---- spawn workers ---------------------------------------------------
        let (report_tx, report_rx) = mpsc::channel::<Report>();
        let mut handles = Vec::new();
        let mut groups: Vec<std::sync::Arc<GroupComm>> = Vec::new();
        for (p, stage) in plan.stages.iter().enumerate() {
            let g = stage.devices.len();
            let secs_per_byte = match &opts.emulate {
                Some(cluster) if g > 1 => {
                    let bw = cluster.min_bandwidth(&stage.devices);
                    2.0 * (g as f64 - 1.0) / (g as f64 * bw)
                }
                _ => 0.0,
            };
            groups.push(GroupComm::new(g, secs_per_byte));
            for (slot, &dev) in stage.devices.iter().enumerate() {
                // Bounded-staleness policies carry their stash-ring
                // depth (the timeline's effective admission window)
                // into the worker; synchronous policies pass 0.
                let stash_slots = if opts.policy.max_staleness() > 0 {
                    sched.timeline_at(p, slot).map(|tl| tl.kp).unwrap_or(0)
                } else {
                    0
                };
                let spec = WorkerSpec {
                    stage: p,
                    layers: stage.layers,
                    slot,
                    script: sched.compute_script(p, slot),
                    stash_slots,
                    num_micro: m_total,
                    is_first: p == 0,
                    is_last: p + 1 == n_stages,
                    seed: opts.seed,
                    opt: opts.opt,
                    initial_params: opts.initial_params.clone(),
                };
                let next: Vec<Tx<Msg>> = if p + 1 < n_stages {
                    plan.stages[p + 1]
                        .devices
                        .iter()
                        .zip(&txs[p + 1])
                        .map(|(&to_dev, tx)| shaped(dev, to_dev, tx))
                        .collect()
                } else {
                    Vec::new()
                };
                let prev: Vec<Tx<Msg>> = if p > 0 {
                    plan.stages[p - 1]
                        .devices
                        .iter()
                        .zip(&txs[p - 1])
                        .map(|(&to_dev, tx)| shaped(dev, to_dev, tx))
                        .collect()
                } else {
                    Vec::new()
                };
                let rx = rxs[p][slot].take().unwrap();
                let model_c = model.clone();
                let report_c = report_tx.clone();
                let group_c = groups[p].clone();
                // Outbound wire codecs: activations cross the stage's
                // output boundary, gradients its input boundary.
                let codecs = (
                    opts.codec.at_boundary(stage.layers.1),
                    opts.codec.at_boundary(stage.layers.0),
                );
                handles.push(std::thread::spawn(move || {
                    run_worker(spec, model_c, rx, next, prev, codecs, report_c, group_c)
                }));
            }
        }
        let n_workers = handles.len();

        // ---- training loop ----------------------------------------------------
        let first_g = plan.stages[0].devices.len();
        let last = n_stages - 1;
        let last_g = plan.stages[last].devices.len();
        let mut losses = Vec::with_capacity(opts.steps);
        let mut round_secs = Vec::with_capacity(opts.steps);
        let run_t0 = Instant::now();

        for step in 0..opts.steps {
            let t0 = Instant::now();
            for m in 0..m_total {
                let (input, target) = data.next_microbatch();
                let ib = input.byte_len();
                txs[0][m % first_g].send(ib, Msg::Act { micro: m, t: input })?;
                let tb = target.byte_len();
                txs[last][m % last_g].send(tb, Msg::Targets { micro: m, t: target })?;
            }

            // Round barrier: all workers report.
            let mut loss_sum = 0.0f64;
            let mut micro_seen = 0usize;
            for _ in 0..n_workers {
                match report_rx.recv().context("worker died")? {
                    Report::RoundDone { stage, loss_sum: l, micros, .. } => {
                        if stage == last {
                            loss_sum += l;
                            micro_seen += micros;
                        }
                    }
                    Report::Fatal { stage, slot, error } => {
                        bail!("worker s{stage}/r{slot} failed: {error}");
                    }
                    Report::FinalParams { .. } => {
                        bail!("unexpected FinalParams mid-round");
                    }
                }
            }
            debug_assert_eq!(micro_seen, m_total);
            let loss = loss_sum / m_total as f64;
            losses.push(loss);
            round_secs.push(t0.elapsed().as_secs_f64());
            if opts.log_every > 0 && (step % opts.log_every == 0 || step + 1 == opts.steps) {
                println!(
                    "step {step:>4}  loss {loss:.4}  ({:.2} s/round)",
                    round_secs.last().unwrap()
                );
            }
            // Release the barrier (workers idle at the inter-round wait
            // after the final step, where Stop reaches them cleanly).
            if step + 1 < opts.steps {
                for st in &txs {
                    for tx in st {
                        tx.send(0, Msg::NextRound)?;
                    }
                }
            }
        }

        // ---- shutdown: collect the final weights (checkpoint) -------------------
        for st in &txs {
            for tx in st {
                let _ = tx.send(0, Msg::Stop);
            }
        }
        for h in handles {
            let _ = h.join();
        }
        drop(report_tx);
        let mut final_params = std::collections::BTreeMap::new();
        while let Ok(rep) = report_rx.try_recv() {
            if let Report::FinalParams { layer, values } = rep {
                final_params.insert(layer, values);
            }
        }

        let total = run_t0.elapsed().as_secs_f64();
        let samples = (opts.steps * plan.samples_per_round()) as f64;
        Ok(TrainStats { losses, round_secs, samples_per_sec: samples / total, final_params })
    }
}
