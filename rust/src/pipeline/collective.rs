//! Intra-stage gradient AllReduce for replicated stages.
//!
//! Numerically an average over group members' accumulated gradients;
//! implemented with a shared slot + generation barrier (all members
//! rendezvous, the last arrival reduces, everyone copies the result
//! out).  The *cost* of the ring AllReduce the paper models
//! (2(g-1)/g * W over the slowest link, Eq. 5) is charged explicitly in
//! emulate mode by sleeping the ring transfer time — so live runs show
//! the same synchronisation wall the planner reasons about.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Shared AllReduce context for one stage group.
pub struct GroupComm {
    size: usize,
    inner: Mutex<Slot>,
    cv: Condvar,
    /// ring-time charged per AllReduce in emulate mode (seconds/byte).
    secs_per_byte: f64,
}

struct Slot {
    /// sum accumulator for the current generation
    acc: Vec<f32>,
    arrived: usize,
    generation: u64,
    result: Option<Arc<Vec<f32>>>,
}

impl GroupComm {
    /// `secs_per_byte`: emulated ring cost 2(g-1)/(g*bw) per byte; 0 for
    /// real mode.
    pub fn new(size: usize, secs_per_byte: f64) -> Arc<GroupComm> {
        Arc::new(GroupComm {
            size,
            inner: Mutex::new(Slot { acc: Vec::new(), arrived: 0, generation: 0, result: None }),
            cv: Condvar::new(),
            secs_per_byte,
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Contribute `local` (flattened gradient sum) and receive the
    /// group-wide elementwise SUM.  Blocks until all members arrive.
    pub fn allreduce_sum(&self, local: &[f32]) -> Vec<f32> {
        if self.size == 1 {
            return local.to_vec();
        }
        let mut slot = self.inner.lock().unwrap();
        let my_gen = slot.generation;
        if slot.arrived == 0 {
            slot.acc = local.to_vec();
        } else {
            assert_eq!(slot.acc.len(), local.len(), "gradient length mismatch");
            for (a, b) in slot.acc.iter_mut().zip(local) {
                *a += *b;
            }
        }
        slot.arrived += 1;
        if slot.arrived == self.size {
            // last arrival publishes the result and advances generation
            let result = Arc::new(std::mem::take(&mut slot.acc));
            slot.result = Some(result.clone());
            slot.arrived = 0;
            slot.generation += 1;
            self.cv.notify_all();
            drop(slot);
            self.charge(result.len());
            return (*result).clone();
        }
        // wait for this generation to complete
        while slot.generation == my_gen {
            slot = self.cv.wait(slot).unwrap();
        }
        let result = slot.result.as_ref().unwrap().clone();
        drop(slot);
        self.charge(result.len());
        (*result).clone()
    }

    fn charge(&self, elements: usize) {
        if self.secs_per_byte > 0.0 {
            // Gradients are f32; route the size through the dtype table.
            let bytes = elements * crate::model::from_manifest::DType::F32.size_bytes();
            let secs = self.secs_per_byte * bytes as f64;
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_member_is_identity() {
        let g = GroupComm::new(1, 0.0);
        assert_eq!(g.allreduce_sum(&[1.0, 2.0]), vec![1.0, 2.0]);
    }

    #[test]
    fn three_member_sum() {
        let g = GroupComm::new(3, 0.0);
        let mut handles = Vec::new();
        for k in 0..3 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                let local = vec![k as f32 + 1.0; 4];
                g.allreduce_sum(&local)
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out, vec![6.0; 4]); // 1 + 2 + 3
        }
    }

    #[test]
    fn repeated_generations() {
        let g = GroupComm::new(2, 0.0);
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            let a = g2.allreduce_sum(&[1.0]);
            let b = g2.allreduce_sum(&[10.0]);
            (a, b)
        });
        let a = g.allreduce_sum(&[2.0]);
        let b = g.allreduce_sum(&[20.0]);
        let (ta, tb) = t.join().unwrap();
        assert_eq!(a, vec![3.0]);
        assert_eq!(ta, vec![3.0]);
        assert_eq!(b, vec![30.0]);
        assert_eq!(tb, vec![30.0]);
    }

    #[test]
    fn emulated_ring_cost_delays() {
        let g = GroupComm::new(2, 1e-8); // 10 ns/byte
        let g2 = g.clone();
        let t0 = std::time::Instant::now();
        let t = std::thread::spawn(move || g2.allreduce_sum(&vec![0.0f32; 250_000]));
        g.allreduce_sum(&vec![0.0f32; 250_000]); // 1 MB -> 10 ms
        t.join().unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.009);
    }
}
