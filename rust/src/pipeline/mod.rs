//! Real pipeline execution engine (the paper's Execution Phase, §3.2 +
//! Fig. 11): worker threads with per-thread PJRT runtimes, bandwidth-
//! shaped channels, gradient accumulation, intra-stage AllReduce and
//! in-Rust optimizers.  Micro-batch ordering (1F1B with the K_p
//! warm-up window) is not decided here: the orchestrator builds one
//! `schedule::Schedule` for the round and each worker executes its
//! device's compute script from it.
//!
//! The worker threads execute compiled HLO through the `xla` PJRT
//! binding and only exist under the `pjrt` feature; channels,
//! collectives, optimizers and the `TrainOpts`/`TrainStats` types are
//! feature-independent (the session layer reports through them either
//! way).

pub mod channel;
pub mod collective;
pub mod optimizer;
pub mod train;
#[cfg(feature = "pjrt")]
pub mod worker;

pub use optimizer::{Optimizer, OptimizerCfg};
pub use train::{train, TrainOpts, TrainStats};
#[cfg(feature = "pjrt")]
pub use worker::{Msg, Report, WorkerSpec};
