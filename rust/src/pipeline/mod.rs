//! Real pipeline execution engines (the paper's Execution Phase, §3.2
//! + Fig. 11): script-driven workers, bandwidth-shaped channels,
//! gradient accumulation, intra-stage sync and in-Rust optimizers.
//! Micro-batch ordering (1F1B with the K_p warm-up window) is not
//! decided here: the orchestrator builds one `schedule::Schedule` for
//! the round and each worker executes its device's compute script.
//!
//! Two worker substrates share the transport-agnostic step core of
//! [`step`]:
//!
//! * `worker` — in-process threads executing compiled HLO through
//!   the `xla` PJRT binding (`pjrt` feature only);
//! * [`rpc_worker`] — the `asteroid-worker` process serving the
//!   [`crate::comm::rpc`] protocol over TCP with the
//!   feature-independent [`step::ReferenceStage`] kernel (the
//!   multi-process `session::RpcBackend` drives it).
//!
//! Channels, collectives, optimizers and the `TrainOpts`/`TrainStats`
//! types are feature-independent (the session layer reports through
//! them either way).

pub mod channel;
pub mod collective;
pub mod optimizer;
pub mod rpc_worker;
pub mod step;
pub mod train;
#[cfg(feature = "pjrt")]
pub mod worker;

pub use optimizer::{Optimizer, OptimizerCfg};
pub use step::{ReferenceStage, WorkerSpec};
pub use train::{train, TrainOpts, TrainStats};
#[cfg(feature = "pjrt")]
pub use worker::{Msg, Report};
