//! Real pipeline execution engine (the paper's Execution Phase, §3.2 +
//! Fig. 11): worker threads with per-thread PJRT runtimes, bandwidth-
//! shaped channels, 1F1B micro-batch scheduling, gradient accumulation,
//! intra-stage AllReduce and in-Rust optimizers.

pub mod channel;
pub mod collective;
pub mod optimizer;
pub mod train;
pub mod worker;

pub use optimizer::{Optimizer, OptimizerCfg};
pub use train::{train, TrainOpts, TrainStats};
pub use worker::{Msg, Report, WorkerSpec};
