//! Cluster / device / training configuration.
//!
//! The paper evaluates four edge environments (Table 6) built from three
//! Jetson device classes (Table 5) plus an A100 reference (Table 1).
//! This module models those devices and environments: each device has a
//! memory budget and a *non-linear* batch->latency execution model (the
//! paper's Fig. 6 observation), and each environment has a D2D bandwidth
//! matrix.  Everything can also be loaded from a JSON cluster spec so
//! users can describe their own heterogeneous pools.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::json::Json;

pub const MBPS: f64 = 1e6 / 8.0 * 8.0; // 1 Mbps in bits/s
pub const GIB: u64 = 1024 * 1024 * 1024;

/// Known edge device classes (paper Tables 1 and 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    JetsonNano,
    JetsonTX2,
    JetsonNX,
    A100,
    Custom,
}

impl DeviceKind {
    pub fn parse(s: &str) -> Result<DeviceKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "nano" | "jetson-nano" => DeviceKind::JetsonNano,
            "tx2" | "jetson-tx2" => DeviceKind::JetsonTX2,
            "nx" | "jetson-nx" | "xavier-nx" => DeviceKind::JetsonNX,
            "a100" => DeviceKind::A100,
            "custom" => DeviceKind::Custom,
            other => bail!("unknown device kind {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::JetsonNano => "nano",
            DeviceKind::JetsonTX2 => "tx2",
            DeviceKind::JetsonNX => "nx",
            DeviceKind::A100 => "a100",
            DeviceKind::Custom => "custom",
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            DeviceKind::JetsonNano => "N",
            DeviceKind::JetsonTX2 => "T",
            DeviceKind::JetsonNX => "X",
            DeviceKind::A100 => "A",
            DeviceKind::Custom => "C",
        }
    }
}

/// One edge device: compute model + memory budget.
///
/// Execution-time model (see profiler): the paper observes (Fig. 6)
/// that small batches under-utilise the GPU, making time-vs-batch
/// *affine* rather than proportional.  We model GPU utilisation as
/// `W / (W + work_half)` where `W = flops_per_sample * beta` is the
/// useful work of a layer invocation, giving
///
///   t(beta) = overhead_s + (flops_per_sample * beta + work_half) / peak_flops
///
/// `work_half` is the per-invocation work at which utilisation reaches
/// 50%; it reproduces both the batch-size knee of Fig. 6 and the fact
/// that large-tensor layers (ResNet@224) utilise edge GPUs far better
/// than tiny CIFAR convolutions.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub id: usize,
    pub name: String,
    pub kind: DeviceKind,
    /// Usable training memory budget u_d in bytes (total RAM minus
    /// OS/framework reservation).
    pub mem_bytes: u64,
    /// Peak training throughput in FLOP/s.
    pub peak_flops: f64,
    /// Per-layer-invocation work (FLOPs) at 50% utilisation.
    pub work_half: f64,
    /// Fixed per-kernel-launch overhead in seconds.
    pub overhead_s: f64,
}

impl DeviceSpec {
    /// Built-in device classes calibrated against the paper's Table 1
    /// epoch-time ratios (e.g. A100 ~160x Nano, ~67x TX2 on
    /// MobileNetV2/CIFAR) and Table 5 memory sizes.
    pub fn of_kind(kind: DeviceKind, id: usize) -> DeviceSpec {
        let (mem, flops, half, ovh) = match kind {
            // 4 GB board, ~1.5 GB reserved for OS + runtime.
            DeviceKind::JetsonNano => (2 * GIB + GIB / 2, 472e9, 6.5e9, 2.0e-4),
            DeviceKind::JetsonTX2 => (5 * GIB, 1.33e12, 8.0e9, 1.5e-4),
            DeviceKind::JetsonNX => (5 * GIB + GIB / 2, 2.2e12, 9.0e9, 1.0e-4),
            DeviceKind::A100 => (38 * GIB, 78e12, 6.0e9, 2.0e-5),
            DeviceKind::Custom => (4 * GIB, 1e12, 8.0e9, 2.0e-4),
        };
        DeviceSpec {
            id,
            name: format!("{}{}", kind.short(), id),
            kind,
            mem_bytes: mem,
            peak_flops: flops,
            work_half: half,
            overhead_s: ovh,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("kind", Json::str(self.kind.name())),
            ("mem_bytes", Json::num(self.mem_bytes as f64)),
            ("peak_flops", Json::num(self.peak_flops)),
            ("work_half", Json::num(self.work_half)),
            ("overhead_s", Json::num(self.overhead_s)),
        ])
    }

    pub fn from_json(j: &Json, id: usize) -> Result<DeviceSpec> {
        let kind = DeviceKind::parse(j.get("kind")?.as_str()?)?;
        let mut d = DeviceSpec::of_kind(kind, id);
        if let Some(v) = j.opt("name")? {
            d.name = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("mem_bytes")? {
            d.mem_bytes = v.as_u64()?;
        }
        if let Some(v) = j.opt("peak_flops")? {
            d.peak_flops = v.as_f64()?;
        }
        if let Some(v) = j.opt("work_half")? {
            d.work_half = v.as_f64()?;
        }
        if let Some(v) = j.opt("overhead_s")? {
            d.overhead_s = v.as_f64()?;
        }
        Ok(d)
    }
}

/// A pool of edge devices plus the D2D bandwidth matrix b_{d,d'}
/// (bytes/second, symmetric, diagonal = +inf conceptually).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub devices: Vec<DeviceSpec>,
    /// bandwidth[i][j] in bytes/s; bandwidth[i][i] is unused.
    pub bandwidth: Vec<Vec<f64>>,
    /// One-way message latency in seconds (per D2D transfer).
    pub latency_s: f64,
}

impl ClusterSpec {
    /// Uniform-bandwidth cluster from device kinds (paper's testbeds use
    /// one shared 100 Mbps or 1000 Mbps network).
    pub fn uniform(kinds: &[DeviceKind], mbps: f64) -> ClusterSpec {
        let devices: Vec<DeviceSpec> = kinds
            .iter()
            .enumerate()
            .map(|(i, &k)| DeviceSpec::of_kind(k, i))
            .collect();
        let n = devices.len();
        let bw = mbps * 1e6 / 8.0; // bytes/s
        ClusterSpec {
            devices,
            bandwidth: vec![vec![bw; n]; n],
            latency_s: 2e-3,
        }
    }

    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Minimum link bandwidth among a device group (paper Eq. 5 uses the
    /// slowest link for AllReduce).
    pub fn min_bandwidth(&self, group: &[usize]) -> f64 {
        let mut min = f64::INFINITY;
        for (ai, &a) in group.iter().enumerate() {
            for &b in &group[ai + 1..] {
                min = min.min(self.bandwidth[a][b]);
            }
        }
        min
    }

    /// Bottleneck bandwidth between two device groups (inter-stage link).
    pub fn group_bandwidth(&self, from: &[usize], to: &[usize]) -> f64 {
        let mut min = f64::INFINITY;
        for &a in from {
            for &b in to {
                if a != b {
                    min = min.min(self.bandwidth[a][b]);
                }
            }
        }
        min
    }

    // ------------------------------------------------------- environments

    /// Paper Table 6 environments plus the single-A100 reference.
    pub fn env(name: &str, mbps: f64) -> Result<ClusterSpec> {
        use DeviceKind::*;
        // `nanos:<n>`: n homogeneous Jetson Nanos — the shape the
        // multi-process RPC quickstart and CI pipelines use (worker
        // count is explicit, so `--method pp` gives exactly one stage
        // per worker).
        if let Some(n) = name.strip_prefix("nanos:") {
            let n: usize = n
                .parse()
                .map_err(|_| anyhow::anyhow!("nanos:<n> expects an integer, got {name:?}"))?;
            if n == 0 {
                bail!("nanos:<n> needs at least one device");
            }
            return Ok(ClusterSpec::nanos(n, mbps));
        }
        let kinds: Vec<DeviceKind> = match name.to_ascii_uppercase().as_str() {
            // A: 5 x Nano
            "A" => vec![JetsonNano; 5],
            // B: 3 x NX, 2 x TX2
            "B" => vec![JetsonNX, JetsonNX, JetsonNX, JetsonTX2, JetsonTX2],
            // C: 1 x NX, 2 x TX2, 3 x Nano
            "C" => vec![JetsonNX, JetsonTX2, JetsonTX2, JetsonNano, JetsonNano, JetsonNano],
            // D: 1 x TX2, 3 x Nano
            "D" => vec![JetsonTX2, JetsonNano, JetsonNano, JetsonNano],
            "A100" => vec![A100],
            other => bail!("unknown environment {other:?} (want A/B/C/D/A100, or nanos:<n>)"),
        };
        Ok(ClusterSpec::uniform(&kinds, mbps))
    }

    /// Homogeneous n-Nano cluster (paper Fig. 18 scalability study).
    pub fn nanos(n: usize, mbps: f64) -> ClusterSpec {
        ClusterSpec::uniform(&vec![DeviceKind::JetsonNano; n], mbps)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "devices",
                Json::arr(self.devices.iter().map(|d| d.to_json())),
            ),
            (
                "bandwidth",
                Json::arr(self.bandwidth.iter().map(|row| {
                    Json::arr(row.iter().map(|&b| Json::num(b)))
                })),
            ),
            ("latency_s", Json::num(self.latency_s)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ClusterSpec> {
        let devices = j
            .get("devices")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, d)| DeviceSpec::from_json(d, i))
            .collect::<Result<Vec<_>>>()?;
        let n = devices.len();
        let bandwidth = match j.opt("bandwidth")? {
            Some(b) => {
                let rows = b.as_arr()?;
                if rows.len() != n {
                    bail!("bandwidth matrix is {}x? but {} devices", rows.len(), n);
                }
                rows.iter()
                    .map(|row| {
                        row.as_arr()?
                            .iter()
                            .map(|v| v.as_f64())
                            .collect::<Result<Vec<_>>>()
                    })
                    .collect::<Result<Vec<_>>>()?
            }
            None => {
                let mbps = j.opt("mbps")?.map(|v| v.as_f64()).transpose()?.unwrap_or(100.0);
                vec![vec![mbps * 1e6 / 8.0; n]; n]
            }
        };
        for row in &bandwidth {
            if row.len() != n {
                bail!("bandwidth matrix not square");
            }
        }
        let latency_s = j
            .opt("latency_s")?
            .map(|v| v.as_f64())
            .transpose()?
            .unwrap_or(2e-3);
        Ok(ClusterSpec { devices, bandwidth, latency_s })
    }

    pub fn load(path: &Path) -> Result<ClusterSpec> {
        let j = Json::parse_file(path)?;
        ClusterSpec::from_json(&j).with_context(|| format!("cluster spec {}", path.display()))
    }

    /// Compact description, e.g. "3xNX+2xTX2@100Mbps".
    pub fn describe(&self) -> String {
        let mut counts: Vec<(DeviceKind, usize)> = Vec::new();
        for d in &self.devices {
            match counts.iter_mut().find(|(k, _)| *k == d.kind) {
                Some((_, c)) => *c += 1,
                None => counts.push((d.kind, 1)),
            }
        }
        let devs: Vec<String> = counts
            .iter()
            .map(|(k, c)| format!("{c}x{}", k.name()))
            .collect();
        let bw = self.bandwidth.first().and_then(|r| r.iter().find(|&&b| b > 0.0));
        match bw {
            Some(&b) => format!("{}@{:.0}Mbps", devs.join("+"), b * 8.0 / 1e6),
            None => devs.join("+"),
        }
    }
}

/// Training hyper-parameters relevant to planning and execution.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Global mini-batch size (paper: 2048 for EffNet/MobileNet/Bert,
    /// 256 for ResNet50).
    pub minibatch: usize,
    /// Micro-batch size B injected into the pipeline.
    pub microbatch: usize,
    /// Optimizer memory multiplier over weights (SGD-momentum = 1.0,
    /// Adam = 2.0).
    pub optimizer_mem_factor: f64,
    /// Maximum number of pipeline stages the planner may create.
    pub max_stages: usize,
}

impl TrainConfig {
    pub fn new(minibatch: usize, microbatch: usize) -> TrainConfig {
        assert!(microbatch > 0 && minibatch >= microbatch);
        TrainConfig {
            minibatch,
            microbatch,
            optimizer_mem_factor: 1.0,
            max_stages: 8,
        }
    }

    /// M: micro-batches per HPP-Round.
    pub fn num_microbatches(&self) -> usize {
        (self.minibatch + self.microbatch - 1) / self.microbatch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_presets_ordered_by_power() {
        let nano = DeviceSpec::of_kind(DeviceKind::JetsonNano, 0);
        let tx2 = DeviceSpec::of_kind(DeviceKind::JetsonTX2, 1);
        let nx = DeviceSpec::of_kind(DeviceKind::JetsonNX, 2);
        let a100 = DeviceSpec::of_kind(DeviceKind::A100, 3);
        assert!(nano.peak_flops < tx2.peak_flops);
        assert!(tx2.peak_flops < nx.peak_flops);
        assert!(nx.peak_flops < a100.peak_flops);
        // Rough peak ordering consistent with Table 1 (the precise
        // epoch-time ratios are asserted in profiler::tests against the
        // full execution model, which includes work_half + overhead).
        let r_nano = a100.peak_flops / nano.peak_flops;
        assert!(r_nano > 100.0 && r_nano < 250.0, "{r_nano}");
    }

    #[test]
    fn envs_match_table6() {
        assert_eq!(ClusterSpec::env("A", 100.0).unwrap().n(), 5);
        assert_eq!(ClusterSpec::env("B", 100.0).unwrap().n(), 5);
        assert_eq!(ClusterSpec::env("C", 100.0).unwrap().n(), 6);
        assert_eq!(ClusterSpec::env("D", 100.0).unwrap().n(), 4);
        assert!(ClusterSpec::env("Z", 100.0).is_err());
    }

    #[test]
    fn uniform_bandwidth() {
        let c = ClusterSpec::env("A", 100.0).unwrap();
        let bw = 100.0 * 1e6 / 8.0;
        assert_eq!(c.min_bandwidth(&[0, 1, 2]), bw);
        assert_eq!(c.group_bandwidth(&[0], &[1]), bw);
    }

    #[test]
    fn min_bandwidth_finds_bottleneck() {
        let mut c = ClusterSpec::env("A", 100.0).unwrap();
        c.bandwidth[1][3] = 1.0;
        c.bandwidth[3][1] = 1.0;
        assert_eq!(c.min_bandwidth(&[1, 3]), 1.0);
        assert_eq!(c.min_bandwidth(&[0, 2]), 100.0 * 1e6 / 8.0);
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterSpec::env("C", 1000.0).unwrap();
        let j = c.to_json();
        let c2 = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(c2.n(), c.n());
        assert_eq!(c2.devices[0].kind, DeviceKind::JetsonNX);
        assert_eq!(c2.bandwidth[0][1], c.bandwidth[0][1]);
    }

    #[test]
    fn train_config_microbatches() {
        let t = TrainConfig::new(2048, 32);
        assert_eq!(t.num_microbatches(), 64);
        let t = TrainConfig::new(100, 32);
        assert_eq!(t.num_microbatches(), 4); // ceil
    }

    #[test]
    fn describe_compact() {
        let c = ClusterSpec::env("B", 100.0).unwrap();
        assert_eq!(c.describe(), "3xnx+2xtx2@100Mbps");
    }
}
