//! The Asteroid coordinator: the user-facing orchestration API tying
//! together the three phases of Fig. 3.
//!
//! * **Preprocessing** — build/load profiles for (cluster, model);
//! * **Planning** — run Algorithm 2 (or a baseline planner) to get an
//!   HPP plan;
//! * **Execution** — either simulate the plan (throughput studies) or
//!   run it for real through the PJRT pipeline engine, with the
//!   fault-tolerance machinery available for device-exit events.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{ClusterSpec, TrainConfig};
use crate::data::DataSource;
use crate::fault::{
    heavy_reschedule, lightweight_replay, HeartbeatCfg, RecoveryReport,
};
use crate::model::from_manifest::Manifest;
use crate::model::{zoo, ModelDesc};
use crate::pipeline::{train, TrainOpts, TrainStats};
use crate::planner::baselines::{self, Method};
use crate::planner::dp::{plan_hpp, PlanOutcome, PlannerConfig};
use crate::planner::AllocOpts;
use crate::profiler::ProfileTable;
use crate::sim::{simulate_round, SimResult};

/// A fully-initialised coordination context for one (model, cluster,
/// training-config) triple.
pub struct Coordinator {
    pub cluster: ClusterSpec,
    pub model: ModelDesc,
    pub table: ProfileTable,
    pub cfg: TrainConfig,
    /// Set when the model is an AOT-compiled manifest model (real
    /// execution available).
    pub artifacts: Option<(PathBuf, String)>,
}

impl Coordinator {
    /// Context over a zoo model (simulation-only experiments).
    pub fn for_zoo_model(
        model_name: &str,
        cluster: ClusterSpec,
        cfg: TrainConfig,
    ) -> Result<Coordinator> {
        let model = zoo::by_name(model_name)
            .with_context(|| format!("unknown zoo model {model_name:?}"))?;
        let table = ProfileTable::new(&cluster, &model);
        Ok(Coordinator { cluster, model, table, cfg, artifacts: None })
    }

    /// Context over an AOT-compiled manifest model (real execution).
    pub fn for_artifact_model(
        artifacts_dir: &Path,
        model_name: &str,
        cluster: ClusterSpec,
        cfg: TrainConfig,
    ) -> Result<Coordinator> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mm = manifest.model(model_name)?;
        anyhow::ensure!(
            cfg.microbatch == mm.microbatch,
            "training config micro-batch {} != compiled micro-batch {}",
            cfg.microbatch,
            mm.microbatch
        );
        let model = mm.to_model_desc();
        let table = ProfileTable::new(&cluster, &model);
        Ok(Coordinator {
            cluster,
            model,
            table,
            cfg,
            artifacts: Some((artifacts_dir.to_path_buf(), model_name.to_string())),
        })
    }

    /// Planning phase with Asteroid's planner.
    pub fn plan(&self) -> Result<PlanOutcome> {
        plan_hpp(&self.table, &self.cluster, &self.model, &self.cfg, &PlannerConfig::default())
    }

    /// Planning with an explicit planner configuration (ablations).
    pub fn plan_with(&self, pc: &PlannerConfig) -> Result<PlanOutcome> {
        plan_hpp(&self.table, &self.cluster, &self.model, &self.cfg, pc)
    }

    /// Planning with one of the baseline methods.  HetPipe has a
    /// different architecture (HDP) — use `baselines::plan_hetpipe`
    /// directly for its analytic result.
    pub fn plan_baseline(&self, method: Method) -> Result<PlanOutcome> {
        match method {
            Method::Asteroid => self.plan(),
            Method::DataParallel | Method::Eddl => baselines::plan_dp(
                &self.table,
                &self.cluster,
                &self.model,
                &self.cfg,
                AllocOpts::default(),
            ),
            Method::GpipePP => {
                baselines::plan_gpipe_pp(&self.table, &self.cluster, &self.model, &self.cfg)
            }
            Method::PipeDream => {
                baselines::plan_pipedream(&self.table, &self.cluster, &self.model, &self.cfg)
            }
            Method::Dapple => {
                baselines::plan_dapple(&self.table, &self.cluster, &self.model, &self.cfg)
            }
            Method::HetPipe => anyhow::bail!("HetPipe uses the HDP path (plan_hetpipe)"),
            Method::OnDevice => self.plan_on_device(),
        }
    }

    /// On-device baseline: single strongest device, single stage.
    pub fn plan_on_device(&self) -> Result<PlanOutcome> {
        let best = self
            .cluster
            .devices
            .iter()
            .max_by(|a, b| a.peak_flops.partial_cmp(&b.peak_flops).unwrap())
            .unwrap()
            .id;
        let mut single = self.cluster.clone();
        single.devices = vec![self.cluster.devices[best].clone()];
        single.devices[0].id = 0;
        single.bandwidth = vec![vec![0.0]];
        let table = ProfileTable::new(&single, &self.model);
        let mut out =
            plan_hpp(&table, &single, &self.model, &self.cfg, &PlannerConfig::default())?;
        // map back to the original device id
        for s in &mut out.plan.stages {
            for d in &mut s.devices {
                *d = best;
            }
        }
        Ok(out)
    }

    /// Execution phase, simulated (event-accurate schedule).
    pub fn simulate(&self, plan: &crate::planner::Plan) -> SimResult {
        simulate_round(&self.table, &self.cluster, &self.model, plan)
    }

    /// Execution phase, real (PJRT pipeline engine).
    pub fn train(
        &self,
        plan: &crate::planner::Plan,
        opts: &TrainOpts,
        data: &mut dyn DataSource,
    ) -> Result<TrainStats> {
        let (dir, name) = self
            .artifacts
            .as_ref()
            .context("real training requires an artifact model (for_artifact_model)")?;
        train(dir, name, plan, opts, data)
    }

    /// Real training with a live device-exit at `fail_after` rounds:
    /// train, checkpoint (the workers stream their final weights back),
    /// lightweight-replan without the failed device, warm-start the new
    /// pipeline from the checkpoint, and continue — the loss curve must
    /// continue where it left off, which is what the integration tests
    /// assert.  Returns (stats before, recovery report, stats after).
    pub fn train_with_failure(
        &self,
        plan: &crate::planner::Plan,
        opts: &TrainOpts,
        data: &mut dyn DataSource,
        fail_after: usize,
        failed_dev: usize,
        steps_after: usize,
    ) -> Result<(TrainStats, RecoveryReport, TrainStats)> {
        let (dir, name) = self
            .artifacts
            .as_ref()
            .context("real training requires an artifact model")?;

        // Phase 1: train until the failure; final_params is the live
        // checkpoint (replication topology of fault::replication).
        let mut before_opts = opts.clone();
        before_opts.steps = fail_after;
        let before = train(dir, name, plan, &before_opts, data)?;

        // Phase 2: lightweight replay — replan without the failed
        // device (timing model for the report; the weights come from
        // the in-memory checkpoint).
        let report = self.recover_lightweight(plan, failed_dev)?;

        // Phase 3: resume on the new plan, warm-started.
        let mut after_opts = opts.clone();
        after_opts.steps = steps_after;
        after_opts.initial_params = Some(std::sync::Arc::new(before.final_params.clone()));
        let after = train(dir, name, &report.new_plan, &after_opts, data)?;
        Ok((before, report, after))
    }

    /// Device-exit recovery via lightweight pipeline replay.
    pub fn recover_lightweight(
        &self,
        plan: &crate::planner::Plan,
        failed_dev: usize,
    ) -> Result<RecoveryReport> {
        lightweight_replay(
            &self.table,
            &self.cluster,
            &self.model,
            &self.cfg,
            plan,
            failed_dev,
            &HeartbeatCfg::default(),
        )
    }

    /// Device-exit recovery via the heavy-rescheduling baseline.
    pub fn recover_heavy(
        &self,
        plan: &crate::planner::Plan,
        failed_dev: usize,
    ) -> Result<RecoveryReport> {
        heavy_reschedule(
            &self.table,
            &self.cluster,
            &self.model,
            &self.cfg,
            plan,
            failed_dev,
            &HeartbeatCfg::default(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_coordinator_plans_and_simulates() {
        let c = Coordinator::for_zoo_model(
            "mobilenetv2",
            ClusterSpec::env("B", 100.0).unwrap(),
            TrainConfig::new(256, 16),
        )
        .unwrap();
        let out = c.plan().unwrap();
        let sim = c.simulate(&out.plan);
        assert!(sim.throughput > 0.0);
    }

    #[test]
    fn baseline_planners_reachable() {
        let c = Coordinator::for_zoo_model(
            "mobilenetv2",
            ClusterSpec::env("A", 100.0).unwrap(),
            TrainConfig::new(128, 16),
        )
        .unwrap();
        for m in [
            Method::DataParallel,
            Method::GpipePP,
            Method::PipeDream,
            Method::Dapple,
            Method::OnDevice,
        ] {
            let out = c.plan_baseline(m).unwrap();
            assert!(out.predicted_throughput > 0.0, "{m:?}");
        }
        assert!(c.plan_baseline(Method::HetPipe).is_err());
    }

    #[test]
    fn on_device_uses_strongest() {
        let c = Coordinator::for_zoo_model(
            "mobilenetv2",
            ClusterSpec::env("C", 100.0).unwrap(), // NX is device 0
            TrainConfig::new(128, 16),
        )
        .unwrap();
        let out = c.plan_on_device().unwrap();
        assert_eq!(out.plan.num_stages(), 1);
        assert_eq!(out.plan.stages[0].devices, vec![0]);
    }

    #[test]
    fn recovery_paths_work() {
        let c = Coordinator::for_zoo_model(
            "efficientnet-b1",
            ClusterSpec::env("D", 100.0).unwrap(),
            TrainConfig::new(256, 16),
        )
        .unwrap();
        let plan = c.plan().unwrap().plan;
        let failed = *plan.devices().last().unwrap();
        let lite = c.recover_lightweight(&plan, failed).unwrap();
        let heavy = c.recover_heavy(&plan, failed).unwrap();
        assert!(lite.total_s() < heavy.total_s());
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(Coordinator::for_zoo_model(
            "nope",
            ClusterSpec::env("A", 100.0).unwrap(),
            TrainConfig::new(64, 8),
        )
        .is_err());
    }
}
