//! In-tree property-testing harness (proptest is not vendored offline).
//!
//! `check(cases, gen, prop)` runs `prop` against `cases` generated
//! inputs; on failure it reports the case index and seed so the exact
//! input can be regenerated.  Deterministic by default (fixed base
//! seed) so CI is stable; set `ASTEROID_PROPTEST_SEED` to explore.

use super::rng::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`.  Panics with the seed of
/// the first failing case.
pub fn check<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = std::env::var("ASTEROID_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA57E_401D_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case}/{cases} (seed {seed:#x}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Assert helper used inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check(
            100,
            |rng| (rng.below(100), rng.below(100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("addition not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_invalid_property() {
        check(
            100,
            |rng| rng.below(100),
            |&a| if a < 95 { Ok(()) } else { Err(format!("{a} >= 95")) },
        );
    }

    #[test]
    fn generator_sees_distinct_seeds() {
        let mut values = std::collections::HashSet::new();
        check(
            50,
            |rng| rng.next_u64(),
            |&v| {
                values.insert(v);
                Ok(())
            },
        );
        assert!(values.len() > 40, "seeds not distinct enough");
    }
}
