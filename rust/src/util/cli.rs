//! Tiny command-line parser (clap is not vendored in this environment).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with typed accessors, defaults and a generated
//! usage string.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `bool_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} expects a value"))?;
                    out.options.insert(name.to_string(), v);
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                bail!("short options are not supported: {arg}");
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env(bool_flags: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string), &["verbose", "dry-run"]).unwrap()
    }

    #[test]
    fn parses_positional_and_options() {
        let a = args("plan --model lm --devices=5 cluster.json");
        assert_eq!(a.positional, vec!["plan", "cluster.json"]);
        assert_eq!(a.get("model"), Some("lm"));
        assert_eq!(a.usize_or("devices", 0).unwrap(), 5);
    }

    #[test]
    fn parses_bool_flags() {
        let a = args("run --verbose --steps 10");
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("dry-run"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(["--model".to_string()].into_iter(), &[]);
        assert!(r.is_err());
    }

    #[test]
    fn typed_errors() {
        let a = args("x --n abc");
        assert!(a.usize_or("n", 1).is_err());
        assert!(a.require("missing").is_err());
        assert_eq!(a.f64_or("missing", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn rejects_short_options() {
        assert!(Args::parse(["-x".to_string()].into_iter(), &[]).is_err());
    }
}
