//! Minimal JSON parser + writer.
//!
//! The build environment is offline and `serde_json` is not vendored, so
//! Asteroid ships its own JSON substrate.  It supports the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null) and is used for the artifact manifest, cluster specs, plan
//! files and experiment result output.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- typed accessors

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {}", other.kind()),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.kind()),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {}", other.kind()),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => bail!("expected object, got {}", other.kind()),
        }
    }

    /// Field access on an object; errors mention the missing key.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional field access: `Ok(None)` when the key is absent or null.
    pub fn opt(&self, key: &str) -> Result<Option<&Json>> {
        Ok(match self.as_obj()?.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        })
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // ---------------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // ---------------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ---------------------------------------------------------------- writing

    /// Compact single-line rendering.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.keyword("true", Json::Bool(true)),
            b'f' => self.keyword("false", Json::Bool(false)),
            b'n' => self.keyword("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at offset {}", c as char, self.i),
        }
    }

    fn keyword(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("invalid number {text:?} at offset {start}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape {hex:?}"))?;
                            self.i += 4;
                            // Surrogate pairs for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                    && self.i + 6 <= self.b.len()
                                {
                                    let lo_hex =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(lo_hex, 16)
                                        .map_err(|_| anyhow!("bad \\u escape {lo_hex:?}"))?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("invalid codepoint"))?);
                        }
                        c => bail!("invalid escape \\{}", c as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string at offset {}", self.i - 1),
                c => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.i - 1;
                        if start + len > self.b.len() {
                            bail!("truncated UTF-8 sequence");
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' got {:?} at {}", c as char, self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote\" slash\\ nl\n tab\t unicode: é 日本".into());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é 😀""#).unwrap(),
            Json::Str("é 😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let j = Json::parse(r#"{"n": 1.5, "s": "x"}"#).unwrap();
        assert!(j.get("n").unwrap().as_u64().is_err());
        assert!(j.get("n").unwrap().as_i64().is_err());
        assert!(j.get("s").unwrap().as_f64().is_err());
        assert!(j.get("missing").is_err());
        assert_eq!(j.opt("missing").unwrap(), None);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
