//! Offline-environment substrates: JSON, RNG, CLI parsing, statistics,
//! a micro-bench harness and a property-testing harness.  These replace
//! serde_json / rand / clap / criterion / proptest, none of which are
//! vendored in this build environment.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
