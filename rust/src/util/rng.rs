//! Deterministic pseudo-random numbers (SplitMix64 + xoshiro256**).
//!
//! `rand` is not vendored in this offline environment; the coordinator
//! needs reproducible randomness for parameter initialisation, synthetic
//! data generation and the in-tree property-testing harness, so we ship
//! a small, well-known generator pair: SplitMix64 for seeding and
//! xoshiro256** for the stream.

/// xoshiro256** seeded via SplitMix64.  Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Rejection-free multiply-shift (Lemire); bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Standard normal f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with scaled normals (parameter init).
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * scale;
        }
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
