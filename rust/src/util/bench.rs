//! Micro-benchmark harness (criterion is not vendored offline).
//!
//! Provides warmup, calibrated iteration counts, and mean/p50/p95
//! reporting.  Bench binaries are registered in Cargo.toml with
//! `harness = false` and run under `cargo bench`.

use std::time::Instant;

use super::stats::{human_secs, Summary};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub per_iter_s: Summary,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>12}/iter  (p50 {:>10}, p95 {:>10}, n={} x {})",
            self.name,
            human_secs(self.per_iter_s.mean),
            human_secs(self.per_iter_s.p50),
            human_secs(self.per_iter_s.p95),
            self.per_iter_s.n,
            self.iters,
        );
    }
}

/// Benchmark runner: calibrates an iteration count targeting
/// ~`sample_target_s` per sample, then takes `samples` samples.
pub struct Bencher {
    pub warmup_s: f64,
    pub sample_target_s: f64,
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_s: 0.3,
            sample_target_s: 0.1,
            samples: 12,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_s: 0.05, sample_target_s: 0.02, samples: 5, ..Default::default() }
    }

    /// Benchmark `f`, preventing the result from being optimised away.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_s || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.sample_target_s / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            iters,
            per_iter_s: Summary::of(&samples),
        };
        result.report();
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Mean seconds/iter of the most recent bench with this name.
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.results
            .iter()
            .rev()
            .find(|r| r.name == name)
            .map(|r| r.per_iter_s.mean)
    }
}

/// Deterministic synthetic fleet for scale benchmarks: `n` devices
/// cycling nano/nano/tx2/nx (a 2:1:1 heterogeneous mix) on a uniform
/// `mbps` link.  Pure function of its arguments, so the 128/512/2048
/// bench shapes and the CI budget gate always price the same topology.
pub fn synthetic_fleet(n: usize, mbps: f64) -> crate::config::ClusterSpec {
    use crate::config::DeviceKind;
    const CYCLE: [DeviceKind; 4] = [
        DeviceKind::JetsonNano,
        DeviceKind::JetsonNano,
        DeviceKind::JetsonTX2,
        DeviceKind::JetsonNX,
    ];
    let kinds: Vec<DeviceKind> = (0..n).map(|i| CYCLE[i % CYCLE.len()]).collect();
    crate::config::ClusterSpec::uniform(&kinds, mbps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_fleet_is_deterministic_and_cycles_kinds() {
        let a = synthetic_fleet(128, 100.0);
        let b = synthetic_fleet(128, 100.0);
        assert_eq!(a.devices.len(), 128);
        for (da, db) in a.devices.iter().zip(&b.devices) {
            assert_eq!(da.kind, db.kind);
            assert_eq!(da.id, db.id);
        }
        use crate::config::DeviceKind;
        assert_eq!(a.devices[0].kind, DeviceKind::JetsonNano);
        assert_eq!(a.devices[1].kind, DeviceKind::JetsonNano);
        assert_eq!(a.devices[2].kind, DeviceKind::JetsonTX2);
        assert_eq!(a.devices[3].kind, DeviceKind::JetsonNX);
        assert_eq!(a.devices[4].kind, DeviceKind::JetsonNano);
    }

    #[test]
    fn benches_and_records() {
        let mut b = Bencher { warmup_s: 0.01, sample_target_s: 0.002, samples: 3, results: vec![] };
        let r = b.bench("noop-ish", || (0..100).sum::<u64>());
        assert!(r.per_iter_s.mean > 0.0);
        assert_eq!(b.results.len(), 1);
        assert!(b.mean_of("noop-ish").is_some());
        assert!(b.mean_of("nope").is_none());
    }

    #[test]
    fn faster_code_benches_faster() {
        let mut b = Bencher { warmup_s: 0.01, sample_target_s: 0.002, samples: 3, results: vec![] };
        // black_box the bounds so the sums aren't const-folded away.
        let fast = b
            .bench("fast", || (0..std::hint::black_box(10u64)).sum::<u64>())
            .per_iter_s
            .mean;
        let slow = b
            .bench("slow", || {
                (0..std::hint::black_box(100_000u64)).map(std::hint::black_box).sum::<u64>()
            })
            .per_iter_s
            .mean;
        assert!(slow > fast, "slow {slow} fast {fast}");
    }
}
