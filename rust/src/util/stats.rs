//! Small statistics helpers shared by the metrics module, the bench
//! harness and the experiment reproductions.

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }
}

/// Percentile by linear interpolation over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Exponential moving average, used by the runtime throughput tracker.
#[derive(Debug, Clone, Copy)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Format a byte count for humans (GiB/MiB/KiB).
pub fn human_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.1} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{b} B")
    }
}

/// Format seconds for humans (h/min/s/ms).
pub fn human_secs(s: f64) -> String {
    if s >= 3600.0 {
        format!("{:.2} h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.get(), None);
        e.update(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.0 MiB");
        assert_eq!(human_secs(0.002), "2.00 ms");
        assert_eq!(human_secs(90.0), "1.5 min");
        assert_eq!(human_secs(7200.0), "2.00 h");
    }
}
