//! Deadlock-freedom: the cross-device task dependency graph must be
//! acyclic under *finite* channel capacity.
//!
//! Nodes are (timeline, task position).  Three edge families:
//!
//! * **chain** — each timeline executes its tasks in order;
//! * **comm** — a `Recv` cannot complete before its matching `Send`
//!   (matched on `(from, to, micro, payload)`);
//! * **capacity** — a channel buffers at most `C` undelivered
//!   transfers, so the k-th `Send` on a channel cannot start before
//!   the (k-C)-th `Recv` drained its slot.  `C` is derived from the
//!   two endpoints' effective K_p windows (each end can hold at most
//!   its in-flight window of boundary tensors).
//!
//! Any cycle means the live pipeline would block forever — reported
//! as `ASTR001` with the cycle spelled out.  Unmatched or duplicated
//! transfers (which would also hang, but for a different reason) are
//! `ASTR005`.

use std::collections::HashMap;

use crate::schedule::{Payload, Task};

use super::{task_name, Code, Diagnostic, Target};

/// Check one target's schedule for deadlock (`ASTR001`) and transfer
/// mismatches (`ASTR005`).
pub fn check(t: &Target) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let s = t.schedule;

    // Flat node ids: offsets[ti] + task position.
    let mut offsets = Vec::with_capacity(s.timelines.len());
    let mut n_nodes = 0usize;
    for tl in &s.timelines {
        offsets.push(n_nodes);
        n_nodes += tl.tasks.len();
    }
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
    fn add_edge(succs: &mut [Vec<usize>], preds: &mut [Vec<usize>], a: usize, b: usize) {
        succs[a].push(b);
        preds[b].push(a);
    }

    // Chain edges.
    for (ti, tl) in s.timelines.iter().enumerate() {
        for k in 1..tl.tasks.len() {
            add_edge(&mut succs, &mut preds, offsets[ti] + k - 1, offsets[ti] + k);
        }
    }

    // Transfer endpoints, keyed by (from, to, micro, payload).
    type Key = (usize, usize, usize, Payload);
    let mut sends: HashMap<Key, (usize, u64)> = HashMap::new();
    let mut recvs: HashMap<Key, (usize, u64)> = HashMap::new();
    // Per-channel ordered endpoint lists for capacity back-edges.
    let mut chan_sends: HashMap<(usize, usize, Payload), Vec<usize>> = HashMap::new();
    let mut chan_recvs: HashMap<(usize, usize, Payload), Vec<usize>> = HashMap::new();
    let mut kp_of: HashMap<usize, usize> = HashMap::new();

    for (ti, tl) in s.timelines.iter().enumerate() {
        kp_of.insert(tl.device, tl.kp.max(1));
        for (k, task) in tl.tasks.iter().enumerate() {
            let node = offsets[ti] + k;
            match *task {
                Task::Send { micro, to, payload, bytes } => {
                    let key = (tl.device, to, micro, payload);
                    if sends.insert(key, (node, bytes)).is_some() {
                        let msg = format!(
                            "duplicate Send d{} -> d{} micro {} {:?}",
                            tl.device, to, micro, payload
                        );
                        out.push(Diagnostic::new(Code::CommMismatch, Some(tl.device), msg));
                    }
                    chan_sends.entry((tl.device, to, payload)).or_default().push(node);
                }
                Task::Recv { micro, from, payload, bytes } => {
                    let key = (from, tl.device, micro, payload);
                    if recvs.insert(key, (node, bytes)).is_some() {
                        let msg = format!(
                            "duplicate Recv d{} <- d{} micro {} {:?}",
                            tl.device, from, micro, payload
                        );
                        out.push(Diagnostic::new(Code::CommMismatch, Some(tl.device), msg));
                    }
                    chan_recvs.entry((from, tl.device, payload)).or_default().push(node);
                }
                _ => {}
            }
        }
    }

    // Comm edges + mismatch findings.
    for (key, &(snode, sbytes)) in &sends {
        match recvs.get(key) {
            Some(&(rnode, rbytes)) => {
                add_edge(&mut succs, &mut preds, snode, rnode);
                if sbytes != rbytes {
                    out.push(Diagnostic::new(
                        Code::CommMismatch,
                        Some(key.0),
                        format!(
                            "transfer d{} -> d{} micro {} {:?}: sender says {} bytes, receiver {}",
                            key.0, key.1, key.2, key.3, sbytes, rbytes
                        ),
                    ));
                }
            }
            None => {
                let msg = format!(
                    "Send d{} -> d{} micro {} {:?} has no matching Recv",
                    key.0, key.1, key.2, key.3
                );
                out.push(Diagnostic::new(Code::CommMismatch, Some(key.0), msg));
            }
        }
    }
    for key in recvs.keys().filter(|k| !sends.contains_key(*k)) {
        let msg = format!(
            "Recv d{} <- d{} micro {} {:?} has no matching Send",
            key.1, key.0, key.2, key.3
        );
        out.push(Diagnostic::new(Code::CommMismatch, Some(key.1), msg));
    }

    // Capacity back-edges: on channel (src, dst, payload) the k-th
    // send (in sender program order) waits for the (k - C)-th recv (in
    // receiver program order).  C = both endpoints' windows combined —
    // a deliberately generous bound so no validate-clean schedule is
    // ever flagged, while unbounded-buffer assumptions still are.
    for (chan, snodes) in &chan_sends {
        let Some(rnodes) = chan_recvs.get(chan) else { continue };
        if snodes.len() != rnodes.len() {
            continue; // already reported as ASTR005
        }
        let cap =
            kp_of.get(&chan.0).copied().unwrap_or(1) + kp_of.get(&chan.1).copied().unwrap_or(1);
        for k in cap..snodes.len() {
            add_edge(&mut succs, &mut preds, rnodes[k - cap], snodes[k]);
        }
    }

    // Kahn peel; anything left sits on a cycle.
    let mut indeg: Vec<usize> = preds.iter().map(Vec::len).collect();
    let mut queue: Vec<usize> = (0..n_nodes).filter(|&n| indeg[n] == 0).collect();
    let mut seen = 0usize;
    while let Some(n) = queue.pop() {
        seen += 1;
        for &m in &succs[n] {
            indeg[m] -= 1;
            if indeg[m] == 0 {
                queue.push(m);
            }
        }
    }
    if seen < n_nodes {
        let remaining: Vec<usize> = (0..n_nodes).filter(|&n| indeg[n] > 0).collect();
        out.push(cycle_diagnostic(t, &offsets, &preds, &remaining));
    }
    out
}

/// Walk predecessors inside the stuck set (every stuck node has one)
/// until a node repeats, then report that loop.
fn cycle_diagnostic(
    t: &Target,
    offsets: &[usize],
    preds: &[Vec<usize>],
    remaining: &[usize],
) -> Diagnostic {
    let in_set: std::collections::HashSet<usize> = remaining.iter().copied().collect();
    let mut path = Vec::new();
    let mut at = remaining[0];
    let mut pos: HashMap<usize, usize> = HashMap::new();
    let cycle: Vec<usize> = loop {
        if let Some(&i) = pos.get(&at) {
            break path[i..].to_vec();
        }
        pos.insert(at, path.len());
        path.push(at);
        at = *preds[at]
            .iter()
            .find(|p| in_set.contains(p))
            .expect("stuck node without stuck predecessor");
    };
    let locate = |node: usize| -> (usize, usize) {
        let ti = match offsets.binary_search(&node) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        (ti, node - offsets[ti])
    };
    let mut parts = Vec::new();
    for &node in cycle.iter().rev().take(8) {
        let (ti, k) = locate(node);
        let tl = &t.schedule.timelines[ti];
        parts.push(format!("d{}#{}:{}", tl.device, k, task_name(&tl.tasks[k])));
    }
    let suffix = if cycle.len() > 8 {
        format!(" ... ({} tasks total)", cycle.len())
    } else {
        String::new()
    };
    Diagnostic::new(
        Code::DeadlockCycle,
        None,
        format!("dependency cycle: {}{}", parts.join(" -> "), suffix),
    )
}
