//! Memory abstract interpretation: replay each timeline symbolically
//! and derive the peak resident bytes per device *independently* of
//! the planner's Eq. 3 accounting.
//!
//! The abstract state per timeline is the count of in-flight
//! micro-batches (a `Fwd` pins its activations, the matching `Bwd`
//! releases them — `BwdW` is free, its micro's residency was already
//! released).  On top of that sit the fixed charges (weights +
//! accumulated gradients, optimizer state, weight-stash copies) and a
//! transient transcode buffer when a boundary crosses a non-identity
//! wire codec.  Three findings:
//!
//! * `ASTR002` — the replayed in-flight peak exceeds the timeline's
//!   own encoded K_p window;
//! * `ASTR011` — the derived peak exceeds the device's `mem_bytes`;
//! * `ASTR012` — the derived peak (excluding transcode scratch, which
//!   Eq. 3 deliberately does not price) exceeds what the planner
//!   budgeted via `StageMemory` — an N-version disagreement between
//!   two independent implementations of the same accounting.

use crate::model::from_manifest::DType;
use crate::planner::memory::stage_memory_for_policy;
use crate::schedule::{Payload, Task};

use super::{Code, Diagnostic, Target};

/// Check one target's schedule against device budgets and the
/// planner's own memory model.
pub fn check(t: &Target) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for tl in &t.schedule.timelines {
        if tl.share == 0 {
            continue; // idle slot: no residency
        }
        let Some(stage) = t.plan.stages.get(tl.stage) else {
            continue; // stage index outside the plan: staleness pass reports shape issues
        };
        let (i, j) = stage.layers;
        let w = t.model.weight_bytes_range(i, j);
        let fixed = 2 * w
            + (t.cfg.optimizer_mem_factor * w as f64) as u64
            + tl.stash_copies as u64 * w;
        let input = if i == 0 { t.model.input_bytes } else { t.model.boundary_bytes(i) };
        let act_per_mb = (t.model.act_bytes_range(i, j) + input) * tl.share as u64;

        // Replay: in-flight micro count and peak.
        let mut inflight = 0usize;
        let mut peak = 0usize;
        // Transcode scratch: transient, one transfer at a time, so the
        // charge is the max over the timeline's boundary transfers.
        let mut transcode = 0u64;
        for task in &tl.tasks {
            match *task {
                Task::Fwd { .. } => {
                    inflight += 1;
                    peak = peak.max(inflight);
                }
                Task::Bwd { .. } => inflight = inflight.saturating_sub(1),
                Task::Send { payload, bytes, .. } | Task::Recv { payload, bytes, .. } => {
                    // The boundary a transfer crosses: activations exit
                    // over the stage's output cut j and enter over its
                    // input cut i; gradients mirror that.
                    let boundary = match (payload, matches!(*task, Task::Send { .. })) {
                        (Payload::Activation, true) | (Payload::Gradient, false) => j,
                        (Payload::Activation, false) | (Payload::Gradient, true) => i,
                    };
                    let codec = t.codec.at_boundary(boundary);
                    if !matches!(codec, crate::codec::Codec::Fp32) {
                        transcode = transcode.max(codec.wire_bytes(bytes, DType::F32));
                    }
                }
                Task::BwdW { .. } | Task::AllReduce { .. } => {}
            }
        }

        if peak > tl.kp.max(1) {
            out.push(Diagnostic::new(
                Code::InflightWindow,
                Some(tl.device),
                format!(
                    "replay holds {} in-flight micros but the timeline's window is {} ({})",
                    peak,
                    tl.kp.max(1),
                    t.schedule.policy
                ),
            ));
        }

        let replayed = fixed + peak as u64 * act_per_mb;
        if let Some(dev) = t.cluster.devices.get(tl.device) {
            if replayed + transcode > dev.mem_bytes {
                out.push(Diagnostic::new(
                    Code::MemoryBudget,
                    Some(tl.device),
                    format!(
                        "derived peak {}B (fixed {}B + {} x {}B act + {}B transcode) \
                         exceeds {} budget {}B",
                        replayed + transcode,
                        fixed,
                        peak,
                        act_per_mb,
                        transcode,
                        dev.name,
                        dev.mem_bytes
                    ),
                ));
            }
        }

        // N-version check: the planner must have budgeted at least what
        // the replay observes.  One-sided — the planner may legitimately
        // over-budget (it charges the full window even when the replay's
        // steady state never fills it).
        let planned = stage_memory_for_policy(
            t.model,
            t.cfg,
            i,
            j,
            tl.share,
            stage.kp,
            t.plan.num_micro,
            t.policy,
        )
        .total();
        if replayed > planned {
            out.push(Diagnostic::new(
                Code::MemoryDisagreement,
                Some(tl.device),
                format!(
                    "replay derives {replayed}B peak but the planner budgeted {planned}B (Eq. 3)"
                ),
            ));
        }
    }
    out
}
