//! Static verification of the three machine-generated artifact
//! classes: `Schedule` IR, `Plan` memory accounting, and the RPC
//! control-plane protocol.  Surfaced as `asteroid lint`.
//!
//! Everything the planner and policies emit is checked *before* a
//! worker is spawned, so a bad (policy, plan, K_p, codec) combination
//! shows up as a coded diagnostic instead of a hang, an OOM, or a
//! silently applied stale gradient mid-run.  Four analyses:
//!
//! 1. [`deadlock`] — cross-device task dependency graph (intra-stage
//!    order, Send/Recv comm edges, finite-channel back-edges derived
//!    from the effective K_p window); any cycle is `ASTR001`.
//! 2. [`memory`] — symbolic replay of each timeline tracking
//!    activation residency, weight-stash copies, and codec transcode
//!    buffers, deriving peak bytes per device *independently* of the
//!    planner's Eq. 3 accounting; budget violations are `ASTR011`,
//!    planner/verifier disagreement is `ASTR012` (an N-version check
//!    on `StageMemory`).
//! 3. [`staleness`] — version/staleness dataflow: every Bwd/BwdW
//!    reads a version actually stashed, no gradient older than the
//!    window is applied, sync policies tag all-zero.  Subsumes and
//!    strengthens `Schedule::validate` with coded per-task findings.
//! 4. [`protocol`] — exhaustive enumeration of the driver x worker
//!    control-plane product automaton over the declarative transition
//!    tables in `comm::rpc` (the same tables the live serve loop
//!    dispatches through — there is no second copy of the machine).
//!
//! See `rust/docs/VERIFY.md` for the diagnostic-code table and a
//! worked deadlock example.

use std::fmt;

use crate::codec::CodecSpec;
use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::Plan;
use crate::schedule::{Schedule, SchedulePolicy, Task};
use crate::session::Session;

pub mod deadlock;
pub mod memory;
pub mod protocol;
pub mod staleness;

/// Stable diagnostic codes, one per distinct defect class.  Codes are
/// append-only: a released code never changes meaning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Code {
    /// ASTR001: the cross-device dependency graph has a cycle — the
    /// live pipeline would deadlock.
    DeadlockCycle,
    /// ASTR002: a timeline holds more in-flight micro-batches than
    /// its encoded K_p window.
    InflightWindow,
    /// ASTR003: intra-timeline order violation (Bwd before Fwd, BwdW
    /// before Bwd, Send before its producer, Recv after its consumer).
    OrderViolation,
    /// ASTR004: duplicate compute task for the same micro-batch.
    DuplicateTask,
    /// ASTR005: unmatched or duplicated Send/Recv, or a byte-size
    /// disagreement between the two ends of a transfer.
    CommMismatch,
    /// ASTR006: forward/backward count mismatch at end of round.
    CountMismatch,
    /// ASTR007: a split-backward timeline with BwdW for only some
    /// micro-batches.
    PartialSplit,
    /// ASTR008: nonzero weight-version tag under a synchronous policy.
    SyncNonzeroVersion,
    /// ASTR009: a task reads a weight version that was never stashed
    /// (or disagrees with its forward's version).
    VersionMismatch,
    /// ASTR010: a gradient older than the staleness window would be
    /// applied.
    StalenessWindow,
    /// ASTR011: verifier-derived peak bytes exceed the device budget.
    MemoryBudget,
    /// ASTR012: the verifier's independently derived peak exceeds the
    /// planner's Eq. 3 accounting (N-version disagreement).
    MemoryDisagreement,
    /// ASTR013: unhandled or ambiguous (state, message) pair in the
    /// RPC control-plane product automaton.
    ProtocolHole,
    /// ASTR014: a `--codec` per-boundary override names a boundary
    /// that no planned stage cut produces (silently inert).
    CodecOverride,
}

impl Code {
    /// Every code, in numeric order.
    pub const ALL: [Code; 14] = [
        Code::DeadlockCycle,
        Code::InflightWindow,
        Code::OrderViolation,
        Code::DuplicateTask,
        Code::CommMismatch,
        Code::CountMismatch,
        Code::PartialSplit,
        Code::SyncNonzeroVersion,
        Code::VersionMismatch,
        Code::StalenessWindow,
        Code::MemoryBudget,
        Code::MemoryDisagreement,
        Code::ProtocolHole,
        Code::CodecOverride,
    ];

    /// The stable wire identifier (`ASTR001`..).
    pub fn id(self) -> &'static str {
        match self {
            Code::DeadlockCycle => "ASTR001",
            Code::InflightWindow => "ASTR002",
            Code::OrderViolation => "ASTR003",
            Code::DuplicateTask => "ASTR004",
            Code::CommMismatch => "ASTR005",
            Code::CountMismatch => "ASTR006",
            Code::PartialSplit => "ASTR007",
            Code::SyncNonzeroVersion => "ASTR008",
            Code::VersionMismatch => "ASTR009",
            Code::StalenessWindow => "ASTR010",
            Code::MemoryBudget => "ASTR011",
            Code::MemoryDisagreement => "ASTR012",
            Code::ProtocolHole => "ASTR013",
            Code::CodecOverride => "ASTR014",
        }
    }

    /// One-line human title.
    pub fn title(self) -> &'static str {
        match self {
            Code::DeadlockCycle => "dependency cycle (pipeline would deadlock)",
            Code::InflightWindow => "in-flight micros exceed the K_p window",
            Code::OrderViolation => "task order violation",
            Code::DuplicateTask => "duplicate compute task",
            Code::CommMismatch => "Send/Recv mismatch",
            Code::CountMismatch => "forward/backward count mismatch",
            Code::PartialSplit => "partial split backward",
            Code::SyncNonzeroVersion => "nonzero version tag under sync policy",
            Code::VersionMismatch => "weight version never stashed",
            Code::StalenessWindow => "staleness window exceeded",
            Code::MemoryBudget => "peak memory exceeds device budget",
            Code::MemoryDisagreement => "planner/verifier memory disagreement",
            Code::ProtocolHole => "unhandled RPC (state, message) pair",
            Code::CodecOverride => "codec override names no planned boundary",
        }
    }
}

/// One finding: a code, the device it concerns (when device-scoped),
/// and a human message with the concrete evidence.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// The defect class.
    pub code: Code,
    /// Global device id the finding is anchored to, if any.
    pub device: Option<usize>,
    /// Concrete evidence (task positions, byte counts, versions).
    pub message: String,
}

impl Diagnostic {
    pub(crate) fn new(code: Code, device: Option<usize>, message: String) -> Diagnostic {
        Diagnostic { code, device, message }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.device {
            Some(d) => write!(f, "{} device {}: {}", self.code.id(), d, self.message),
            None => write!(f, "{}: {}", self.code.id(), self.message),
        }
    }
}

/// Everything the analyses need about one planned workload.  Borrowed
/// so a grid runner can lint many (policy, codec, cluster) points
/// without cloning models.
pub struct Target<'a> {
    /// The model the plan partitions.
    pub model: &'a ModelDesc,
    /// Training shape (micro-batch size, optimizer factor).
    pub cfg: &'a TrainConfig,
    /// Device budgets (`mem_bytes`) the memory analysis checks.
    pub cluster: &'a ClusterSpec,
    /// The planner's stage partition and allocation.
    pub plan: &'a Plan,
    /// The schedule IR under analysis.
    pub schedule: &'a Schedule,
    /// The policy that generated the schedule (for Eq. 3 replication).
    pub policy: &'a dyn SchedulePolicy,
    /// Wire codec spec (transcode buffers, override validation).
    pub codec: &'a CodecSpec,
}

impl<'a> Target<'a> {
    /// Borrow every artifact of a built [`Session`].
    pub fn of_session(s: &'a Session) -> Target<'a> {
        Target {
            model: s.model(),
            cfg: s.train_config(),
            cluster: s.cluster(),
            plan: s.plan(),
            schedule: s.schedule(),
            policy: s.policy(),
            codec: s.codec(),
        }
    }
}

/// Run every analysis over one target (including the target-independent
/// protocol check) and return the findings sorted by code, device.
pub fn all(t: &Target) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    out.extend(deadlock::check(t));
    out.extend(memory::check(t));
    out.extend(staleness::check(t));
    out.extend(codec_overrides(t));
    out.extend(protocol::check());
    out.sort_by(|a, b| (a.code, a.device).cmp(&(b.code, b.device)));
    out
}

/// ASTR014: every `--codec` per-boundary override must name a
/// boundary some planned stage cut actually produces — an override on
/// any other layer index is silently inert (no wire ever crosses it).
pub fn codec_overrides(t: &Target) -> Vec<Diagnostic> {
    let cuts: Vec<usize> = t
        .plan
        .stages
        .iter()
        .take(t.plan.stages.len().saturating_sub(1))
        .map(|s| s.layers.1)
        .collect();
    t.codec
        .overrides()
        .filter(|(b, _)| !cuts.contains(&(*b as usize)))
        .map(|(b, c)| {
            Diagnostic::new(
                Code::CodecOverride,
                None,
                format!(
                    "override {}={} names no planned stage boundary (cuts: {:?})",
                    b,
                    c.name(),
                    cuts
                ),
            )
        })
        .collect()
}

/// Short display form of a task for diagnostics.
pub(crate) fn task_name(t: &Task) -> String {
    match t {
        Task::Fwd { micro, version } => format!("Fwd(m{micro} v{version})"),
        Task::Bwd { micro, version } => format!("Bwd(m{micro} v{version})"),
        Task::BwdW { micro, version } => format!("BwdW(m{micro} v{version})"),
        Task::Send { micro, to, payload, .. } => format!("Send(m{micro} {payload:?} -> d{to})"),
        Task::Recv { micro, from, payload, .. } => format!("Recv(m{micro} {payload:?} <- d{from})"),
        Task::AllReduce { bytes } => format!("AllReduce({bytes}B)"),
    }
}
