//! Version/staleness dataflow, plus the structural timeline checks it
//! rides on.  A single in-order replay of each timeline establishes:
//!
//! * ordering — no `Bwd` before its `Fwd`, no `BwdW` before its
//!   `Bwd`, no `Send` before its producer, no `Recv` after its
//!   consumer (`ASTR003`);
//! * uniqueness — one compute task per (kind, micro) (`ASTR004`);
//! * completeness — forward and backward counts agree (`ASTR006`),
//!   and a split-backward timeline defers *every* weight gradient or
//!   none (`ASTR007`);
//! * versions — synchronous policies tag all-zero (`ASTR008`); under
//!   bounded staleness every task reads a version actually stashed
//!   (`ASTR009`) and no gradient older than the window is applied
//!   (`ASTR010`).
//!
//! This subsumes `Schedule::validate`'s per-timeline pass and
//! strengthens it: findings are per-task, coded, and non-fatal, so a
//! single lint run reports every defect instead of the first.

use std::collections::HashMap;

use crate::schedule::{Payload, Task};

use super::{Code, Diagnostic, Target};

/// Check one target's schedule for order, shape and version defects.
pub fn check(t: &Target) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let versioned = t.schedule.max_staleness > 0;
    for tl in &t.schedule.timelines {
        let d = tl.device;
        let window = tl.kp.max(1);
        // micro -> version of its Fwd / Bwd (presence = executed).
        let mut fwd: HashMap<usize, usize> = HashMap::new();
        let mut bwd: HashMap<usize, usize> = HashMap::new();
        let mut bww: HashMap<usize, usize> = HashMap::new();
        let mut updates = 0usize;
        for (k, task) in tl.tasks.iter().enumerate() {
            match *task {
                Task::Fwd { micro, version } => {
                    if fwd.contains_key(&micro) {
                        let msg = format!("second Fwd of micro {micro} at #{k}");
                        out.push(diag(Code::DuplicateTask, d, msg));
                        continue;
                    }
                    if !versioned && version != 0 {
                        let msg = format!(
                            "Fwd of micro {micro} tagged v{version} under sync policy {}",
                            t.schedule.policy
                        );
                        out.push(diag(Code::SyncNonzeroVersion, d, msg));
                    }
                    if versioned && version != updates {
                        let msg = format!(
                            "Fwd of micro {micro} reads v{version} but the live weights \
                             are v{updates}"
                        );
                        out.push(diag(Code::VersionMismatch, d, msg));
                    }
                    fwd.insert(micro, version);
                }
                Task::Bwd { micro, version } => {
                    let Some(&fv) = fwd.get(&micro) else {
                        let msg = format!("Bwd of micro {micro} at #{k} before its Fwd");
                        out.push(diag(Code::OrderViolation, d, msg));
                        continue;
                    };
                    if bwd.contains_key(&micro) {
                        let msg = format!("second Bwd of micro {micro} at #{k}");
                        out.push(diag(Code::DuplicateTask, d, msg));
                        continue;
                    }
                    if !versioned && version != 0 {
                        let msg = format!(
                            "Bwd of micro {micro} tagged v{version} under sync policy {}",
                            t.schedule.policy
                        );
                        out.push(diag(Code::SyncNonzeroVersion, d, msg));
                    }
                    if version != fv {
                        let msg = format!(
                            "Bwd of micro {micro} reads v{version} but its Fwd stashed v{fv}"
                        );
                        out.push(diag(Code::VersionMismatch, d, msg));
                    }
                    if versioned {
                        let lag = updates.saturating_sub(version);
                        if lag + 1 > window {
                            let msg = format!(
                                "Bwd of micro {micro} applies a gradient {lag} updates stale \
                                 (window {window})"
                            );
                            out.push(diag(Code::StalenessWindow, d, msg));
                        }
                        updates += 1;
                    }
                    bwd.insert(micro, version);
                }
                Task::BwdW { micro, version } => {
                    let Some(&bv) = bwd.get(&micro) else {
                        let msg = format!("BwdW of micro {micro} at #{k} before its Bwd");
                        out.push(diag(Code::OrderViolation, d, msg));
                        continue;
                    };
                    if bww.contains_key(&micro) {
                        let msg = format!("second BwdW of micro {micro} at #{k}");
                        out.push(diag(Code::DuplicateTask, d, msg));
                        continue;
                    }
                    if version != bv {
                        let msg = format!(
                            "BwdW of micro {micro} reads v{version} but its Bwd used v{bv}"
                        );
                        out.push(diag(Code::VersionMismatch, d, msg));
                    }
                    bww.insert(micro, version);
                }
                Task::Send { micro, payload, .. } => {
                    let produced = match payload {
                        Payload::Activation => fwd.contains_key(&micro),
                        Payload::Gradient => bwd.contains_key(&micro),
                    };
                    if !produced {
                        let msg = format!(
                            "Send of micro {micro} {payload:?} at #{k} before its producer"
                        );
                        out.push(diag(Code::OrderViolation, d, msg));
                    }
                }
                Task::Recv { micro, payload, .. } => {
                    let consumed = match payload {
                        Payload::Activation => fwd.contains_key(&micro),
                        Payload::Gradient => bwd.contains_key(&micro),
                    };
                    if consumed {
                        let msg =
                            format!("Recv of micro {micro} {payload:?} at #{k} after its consumer");
                        out.push(diag(Code::OrderViolation, d, msg));
                    }
                }
                Task::AllReduce { .. } => {}
            }
        }
        if !bww.is_empty() && bww.len() != bwd.len() {
            out.push(diag(
                Code::PartialSplit,
                d,
                format!("split backward covers {} of {} micros", bww.len(), bwd.len()),
            ));
        }
        if fwd.len() != bwd.len() {
            out.push(diag(
                Code::CountMismatch,
                d,
                format!("{} forwards but {} backwards", fwd.len(), bwd.len()),
            ));
        }
    }
    out
}

fn diag(code: Code, device: usize, message: String) -> Diagnostic {
    Diagnostic::new(code, Some(device), message)
}
