//! Protocol state-machine check: exhaustively enumerate the driver x
//! worker control-plane product automaton over the declarative
//! transition tables in [`crate::comm::rpc`].
//!
//! The worker serve loop dispatches through the *same*
//! `WORKER_TRANSITIONS` table this pass checks (there is no second
//! copy of the machine), so a hole found here is a hole the live
//! system would hit.  Three findings, all `ASTR013`:
//!
//! * a (phase, message kind) pair with no table entry — the receiver
//!   would have no defined response;
//! * a pair with more than one entry — the dispatch is ambiguous;
//! * a product-automaton hole — a message one side can emit toward a
//!   peer phase whose table does not define the pair (connections are
//!   FIFO, so the emission tables bound the arrival contexts that
//!   must be covered).

use crate::comm::rpc::{
    DriverAction, DriverPhase, WorkerAction, WorkerPhase, DRIVER_EMITS, DRIVER_TRANSITIONS,
    MSG_KINDS, WORKER_EMITS, WORKER_TRANSITIONS,
};

use super::{Code, Diagnostic};

/// Check the crate's live transition tables.
pub fn check() -> Vec<Diagnostic> {
    check_tables(WORKER_TRANSITIONS, DRIVER_TRANSITIONS)
}

/// Check arbitrary tables (public so mutation tests can knock an
/// entry out and watch the diagnostic appear).
pub fn check_tables(
    worker: &[(WorkerPhase, &str, WorkerAction)],
    driver: &[(DriverPhase, &str, DriverAction)],
) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    // Totality + unambiguity of each side's table.
    for phase in WorkerPhase::ALL {
        for kind in MSG_KINDS {
            let n = worker.iter().filter(|&&(p, k, _)| p == phase && k == kind).count();
            if n == 0 {
                out.push(hole(format!("worker {} has no transition for {kind}", phase.name())));
            } else if n > 1 {
                out.push(hole(format!(
                    "worker {} has {n} transitions for {kind} (ambiguous)",
                    phase.name()
                )));
            }
        }
    }
    for phase in DriverPhase::ALL {
        for kind in MSG_KINDS {
            let n = driver.iter().filter(|&&(p, k, _)| p == phase && k == kind).count();
            if n == 0 {
                out.push(hole(format!("driver {} has no transition for {kind}", phase.name())));
            } else if n > 1 {
                out.push(hole(format!(
                    "driver {} has {n} transitions for {kind} (ambiguous)",
                    phase.name()
                )));
            }
        }
    }

    // Entries for kinds that do not exist on the wire.
    for &(p, k, _) in worker {
        if !MSG_KINDS.contains(&k) {
            out.push(hole(format!("worker {} handles unknown message kind {k}", p.name())));
        }
    }
    for &(p, k, _) in driver {
        if !MSG_KINDS.contains(&k) {
            out.push(hole(format!("driver {} handles unknown message kind {k}", p.name())));
        }
    }

    // Product automaton: everything one side can emit must have a
    // defined transition in every peer phase it can arrive in.
    for &(kind, phases) in DRIVER_EMITS {
        for &phase in phases {
            if !worker.iter().any(|&(p, k, _)| p == phase && k == kind) {
                out.push(hole(format!(
                    "driver may send {kind} while the worker is {} — unhandled",
                    phase.name()
                )));
            }
        }
    }
    for &(kind, phases) in WORKER_EMITS {
        for &phase in phases {
            if !driver.iter().any(|&(p, k, _)| p == phase && k == kind) {
                out.push(hole(format!(
                    "worker may send {kind} while the driver is {} — unhandled",
                    phase.name()
                )));
            }
        }
    }

    out
}

fn hole(message: String) -> Diagnostic {
    Diagnostic::new(Code::ProtocolHole, None, message)
}
