//! Asteroid Profiler: per-layer, per-device, per-batch execution times.
//!
//! The paper's profiler measures t_f^{d,l}(beta) and t_b^{d,l}(beta) on
//! the physical boards for every batch size, because execution time is
//! *non-linear* in batch size (Fig. 6).  Our substrate has no Jetson
//! hardware, so the profile is produced by the calibrated device
//! execution model (config::DeviceSpec):
//!
//!   t(beta) = overhead_s + (flops * beta + work_half) / peak_flops
//!
//! The planner only ever consumes the profile through this module's
//! interface, exactly as Asteroid's planner consumes its measured
//! profile — swapping in measured tables would not change any caller.
//!
//! `ProfileTable` precomputes per-device layer prefix sums so the
//! planner's inner loop evaluates stage times T(i->j, beta) in O(1).

use crate::config::{ClusterSpec, DeviceSpec};
use crate::model::ModelDesc;

/// FP execution time of one layer at batch `beta` on `dev`.
pub fn layer_time_fwd(dev: &DeviceSpec, flops_fwd: f64, beta: usize) -> f64 {
    if beta == 0 {
        return 0.0;
    }
    dev.overhead_s + (flops_fwd * beta as f64 + dev.work_half) / dev.peak_flops
}

/// BP execution time of one layer at batch `beta` on `dev`.
pub fn layer_time_bwd(dev: &DeviceSpec, flops_bwd: f64, beta: usize) -> f64 {
    if beta == 0 {
        return 0.0;
    }
    // BP launches ~2 kernels per layer (dgrad + wgrad).
    2.0 * dev.overhead_s + (flops_bwd * beta as f64 + 2.0 * dev.work_half) / dev.peak_flops
}

/// Precomputed profile for (cluster, model): O(1) range queries of
/// t_f/t_b over contiguous layer ranges.
#[derive(Debug, Clone)]
pub struct ProfileTable {
    /// flops_fwd prefix sums: ff[l] = sum of flops_fwd for layers [0, l).
    ff: Vec<f64>,
    /// flops_bwd prefix sums.
    fb: Vec<f64>,
    /// Per-device cached constants.
    devs: Vec<DevConst>,
    pub num_layers: usize,
}

#[derive(Debug, Clone)]
struct DevConst {
    peak: f64,
    work_half: f64,
    overhead: f64,
}

impl ProfileTable {
    pub fn new(cluster: &ClusterSpec, model: &ModelDesc) -> ProfileTable {
        let n_l = model.num_layers();
        let mut ff = vec![0.0; n_l + 1];
        let mut fb = vec![0.0; n_l + 1];
        for (i, l) in model.layers.iter().enumerate() {
            ff[i + 1] = ff[i] + l.flops_fwd;
            fb[i + 1] = fb[i] + l.flops_bwd;
        }
        let devs = cluster
            .devices
            .iter()
            .map(|d| DevConst {
                peak: d.peak_flops,
                work_half: d.work_half,
                overhead: d.overhead_s,
            })
            .collect();
        ProfileTable { ff, fb, devs, num_layers: n_l }
    }

    /// FP time for layers [i, j) at batch `beta` on device `d`.
    pub fn time_fwd(&self, d: usize, i: usize, j: usize, beta: usize) -> f64 {
        debug_assert!(i <= j && j <= self.num_layers);
        if beta == 0 || i == j {
            return 0.0;
        }
        let dc = &self.devs[d];
        let layers = (j - i) as f64;
        let flops = self.ff[j] - self.ff[i];
        layers * (dc.overhead + dc.work_half / dc.peak) + flops * beta as f64 / dc.peak
    }

    /// BP time for layers [i, j) at batch `beta` on device `d`.
    pub fn time_bwd(&self, d: usize, i: usize, j: usize, beta: usize) -> f64 {
        debug_assert!(i <= j && j <= self.num_layers);
        if beta == 0 || i == j {
            return 0.0;
        }
        let dc = &self.devs[d];
        let layers = (j - i) as f64;
        let flops = self.fb[j] - self.fb[i];
        2.0 * layers * (dc.overhead + dc.work_half / dc.peak) + flops * beta as f64 / dc.peak
    }

    /// FP + BP time for layers [i, j) at batch `beta` on device `d`.
    pub fn time_fwd_bwd(&self, d: usize, i: usize, j: usize, beta: usize) -> f64 {
        self.time_fwd(d, i, j, beta) + self.time_bwd(d, i, j, beta)
    }

    /// Total forward FLOPs of layers [i, j) (prefix-sum difference).
    /// Exposed so the planner can form closed-form lower bounds on
    /// stage execution time without enumerating allocations.
    pub fn flops_fwd_range(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i <= j && j <= self.num_layers);
        self.ff[j] - self.ff[i]
    }

    /// Total backward FLOPs of layers [i, j).
    pub fn flops_bwd_range(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i <= j && j <= self.num_layers);
        self.fb[j] - self.fb[i]
    }

    /// Computing capacity v_d of Eq. (9): inverse FP+BP time over the
    /// stage's layers with a full micro-batch.
    pub fn capacity(&self, d: usize, i: usize, j: usize, micro: usize) -> f64 {
        let t = self.time_fwd_bwd(d, i, j, micro);
        if t <= 0.0 {
            0.0
        } else {
            1.0 / t
        }
    }
}

/// Estimated wall-clock cost of running the *measurement* pass itself
/// (paper Table 8: total profiling time per device).  The profiler
/// measures every layer at batch sizes 1..=max_batch with `repeats`
/// repetitions of FP and BP.
pub fn profiling_cost(
    dev: &DeviceSpec,
    model: &ModelDesc,
    max_batch: usize,
    repeats: usize,
) -> f64 {
    let mut total = 0.0;
    let mut beta = 1;
    while beta <= max_batch {
        for l in &model.layers {
            total += repeats as f64
                * (layer_time_fwd(dev, l.flops_fwd, beta)
                    + layer_time_bwd(dev, l.flops_bwd, beta));
        }
        beta *= 2; // power-of-two batch sweep
    }
    total
}

/// Per-sample training time of the whole model on a single device at a
/// given batch size (Table 1 epoch-time reproduction).
pub fn on_device_sample_time(dev: &DeviceSpec, model: &ModelDesc, batch: usize) -> f64 {
    let mut t = 0.0;
    for l in &model.layers {
        t += layer_time_fwd(dev, l.flops_fwd, batch) + layer_time_bwd(dev, l.flops_bwd, batch);
    }
    t / batch as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, DeviceKind, DeviceSpec};
    use crate::model::zoo;

    fn nano() -> DeviceSpec {
        DeviceSpec::of_kind(DeviceKind::JetsonNano, 0)
    }

    #[test]
    fn batch_time_is_nonlinear() {
        // Fig. 6: doubling the batch must NOT double the time (fixed
        // under-utilisation cost dominates at small batches).
        let d = nano();
        let t1 = layer_time_fwd(&d, 1e8, 1);
        let t2 = layer_time_fwd(&d, 1e8, 2);
        let t32 = layer_time_fwd(&d, 1e8, 32);
        assert!(t2 < 2.0 * t1, "t2={t2} t1={t1}");
        assert!(t32 < 32.0 * t1);
        // ... but time is still monotone in batch.
        assert!(t2 > t1 && t32 > t2);
    }

    #[test]
    fn zero_batch_is_free() {
        let d = nano();
        assert_eq!(layer_time_fwd(&d, 1e9, 0), 0.0);
        assert_eq!(layer_time_bwd(&d, 1e9, 0), 0.0);
    }

    #[test]
    fn bwd_slower_than_fwd() {
        let d = nano();
        assert!(layer_time_bwd(&d, 2e8, 8) > layer_time_fwd(&d, 1e8, 8));
    }

    #[test]
    fn profile_table_matches_direct_sum() {
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        for d in 0..cluster.n() {
            let dev = &cluster.devices[d];
            for (i, j) in [(0, 5), (3, 20), (0, model.num_layers())] {
                let direct: f64 = model.layers[i..j]
                    .iter()
                    .map(|l| layer_time_fwd(dev, l.flops_fwd, 16))
                    .sum();
                let fast = table.time_fwd(d, i, j, 16);
                assert!(
                    (direct - fast).abs() < 1e-9,
                    "d={d} range=({i},{j}): {direct} vs {fast}"
                );
            }
        }
    }

    #[test]
    fn faster_device_has_higher_capacity() {
        let cluster = ClusterSpec::env("C", 100.0).unwrap(); // NX, TX2 x2, Nano x3
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let nx = table.capacity(0, 0, nl, 16);
        let tx2 = table.capacity(1, 0, nl, 16);
        let nano = table.capacity(3, 0, nl, 16);
        assert!(nx > tx2 && tx2 > nano, "nx={nx} tx2={tx2} nano={nano}");
    }

    #[test]
    fn table1_epoch_ratios_hold() {
        // Reproduces the *ratios* of Table 1: A100 vastly faster than the
        // Jetson boards on MobileNetV2.
        let model = zoo::mobilenet_v2();
        let a100 = on_device_sample_time(&DeviceSpec::of_kind(DeviceKind::A100, 0), &model, 32);
        let nano = on_device_sample_time(&nano(), &model, 32);
        let tx2 =
            on_device_sample_time(&DeviceSpec::of_kind(DeviceKind::JetsonTX2, 0), &model, 32);
        let r_nano = nano / a100;
        let r_tx2 = tx2 / a100;
        assert!(r_nano > 80.0 && r_nano < 320.0, "nano/a100 = {r_nano}");
        assert!(r_tx2 > 30.0 && r_tx2 < 140.0, "tx2/a100 = {r_tx2}");
        assert!(r_nano > r_tx2);
    }

    #[test]
    fn profiling_cost_scales_with_layers_and_speed() {
        let effnet = zoo::efficientnet_b1();
        let bert = zoo::bert_small();
        let d_nano = nano();
        let d_nx = DeviceSpec::of_kind(DeviceKind::JetsonNX, 0);
        // Table 8: Nano profiles slowest; more layers cost more.
        assert!(profiling_cost(&d_nano, &effnet, 256, 3) > profiling_cost(&d_nx, &effnet, 256, 3));
        assert!(
            profiling_cost(&d_nano, &effnet, 256, 3) > profiling_cost(&d_nano, &bert, 256, 3) / 10.0
        );
    }
}
