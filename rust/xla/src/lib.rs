//! Offline stand-in for the `xla-rs` PJRT binding.
//!
//! Mirrors the subset of the real crate's API that the Asteroid
//! runtime consumes (see `rust/src/runtime/` and
//! `rust/src/pipeline/worker.rs`).  Host-side `Literal`s are real byte
//! buffers — construction, shape queries and readback work — while
//! anything requiring native XLA (client creation, HLO parsing,
//! compilation, execution) returns [`Error`] at runtime.  README.md
//! explains how to swap in the real binding.

use std::borrow::Borrow;
use std::fmt;

/// Error type matching the real binding's `anyhow`-compatible shape.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} requires the real PJRT binding \
         (repoint the `xla` dependency at xla-rs; see rust/xla/README.md)"
    )))
}

/// Element types of the real binding that this repo's artifacts use,
/// plus the common ones so `match` sites stay non-trivial.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    fn size_bytes(&self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Host value types `Literal::to_vec` can read back.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_ne(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne(bytes: &[u8]) -> f32 {
        f32::from_ne_bytes(bytes.try_into().unwrap())
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne(bytes: &[u8]) -> i32 {
        i32::from_ne_bytes(bytes.try_into().unwrap())
    }
}

/// Array shape: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host literal: a typed, shaped byte buffer.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let elements: usize = dims.iter().product();
        if elements * ty.size_bytes() != untyped_data.len() {
            return Err(Error(format!(
                "literal data is {} bytes but shape {dims:?} of {ty:?} wants {}",
                untyped_data.len(),
                elements * ty.size_bytes()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: untyped_data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(self.ty.size_bytes())
            .map(T::from_ne)
            .collect())
    }

    /// Tuple literals only come out of executions, which the stub
    /// cannot perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("destructuring an execution result tuple")
    }
}

/// Parsed HLO module (never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        unavailable(&format!("parsing HLO text {path:?}"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // Unreachable in practice: no HloModuleProto can exist here.
        XlaComputation { _private: () }
    }
}

/// A device buffer handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("reading back a device buffer")
    }
}

/// A compiled executable (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a compiled artifact")
    }
}

/// The PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("creating a PJRT CPU client")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an XLA computation")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_works_on_host() {
        let v: Vec<f32> = vec![1.0, -2.5, 3.25, 0.0, 9.0, 7.5];
        let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
                .unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), v);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_size_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 3])
                .is_err()
        );
    }

    #[test]
    fn native_execution_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("real PJRT binding"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
