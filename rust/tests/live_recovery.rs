//! Live fault-tolerance integration: a device exits mid-training and
//! the pipeline replays — real PJRT execution before and after, with
//! the checkpointed weights carried across the re-planning.  The exit
//! is injected declaratively: a `FaultSpec` on the session, one
//! `PjrtBackend` run.
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::data::LmTask;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::{train, OptimizerCfg, TrainOpts};
use asteroid::session::{FaultSpec, PjrtBackend, Session};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn training_survives_device_exit_with_warm_weights() {
    let artifacts = artifacts_dir();
    let manifest = Manifest::load(&artifacts).expect("run `make artifacts` first");
    let lm = manifest.model("lm").unwrap();
    let micro = lm.microbatch;
    let vocab = lm.cfg_usize("vocab").unwrap();

    // 3-device cluster so losing one still leaves a pipeline.
    let session = Session::builder()
        .artifact_model(&artifacts, "lm")
        .cluster(ClusterSpec::env("D", 1000.0).unwrap())
        .train(TrainConfig::new(micro * 4, micro))
        .optimizer(OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 })
        .seed(11)
        .log_every(0)
        .build()
        .unwrap();
    assert!(session.plan().devices().len() >= 2, "need a multi-device plan");

    let fail_after = 8;
    let report = session
        .with_fault(FaultSpec::last_planned().after(fail_after).resume_for(6))
        .run(&mut PjrtBackend::new())
        .unwrap();

    // One unified report: the recovery event sits between the phases.
    assert_eq!(report.rounds, fail_after + 6);
    assert_eq!(report.losses.len(), report.rounds);
    let event = &report.recoveries[0];
    assert_eq!(event.round, fail_after);

    // The replayed pipeline excludes the failed device.
    assert!(!event.report.new_plan.devices().contains(&event.failed_device));

    // Loss must *continue*, not restart: the first post-recovery loss
    // stays close to the last pre-failure loss, far below a cold
    // restart at ln(V).
    let last_before = report.losses[fail_after - 1];
    let first_after = report.losses[fail_after];
    let cold = (vocab as f64).ln();
    assert!(
        first_after < last_before + 0.4,
        "warm-start lost progress: {last_before} -> {first_after}"
    );
    assert!(
        first_after < cold - 0.5,
        "looks like a cold restart: {first_after} vs ln(V) = {cold}"
    );
    // ... and training keeps improving afterwards.
    let final_loss = *report.losses.last().unwrap();
    assert!(final_loss <= first_after + 0.05, "{first_after} -> {final_loss}");
    // The checkpoint stream survives to the end of the run.
    assert!(report.final_params.is_some());
}

#[test]
fn checkpoint_roundtrip_preserves_training_state() {
    // Train k steps, stop, warm-start a fresh pipeline from the final
    // weights: the loss must continue exactly as if uninterrupted.
    // (Engine-level test: drives pipeline::train on a hand-built plan.)
    let artifacts = artifacts_dir();
    let manifest = Manifest::load(&artifacts).unwrap();
    let lm = manifest.model("lm").unwrap();
    let micro = lm.microbatch;
    let vocab = lm.cfg_usize("vocab").unwrap();
    let seq = lm.cfg_usize("seq").unwrap();
    let nl = lm.layers.len();

    let plan = asteroid::planner::Plan {
        stages: vec![asteroid::planner::Stage {
            layers: (0, nl),
            devices: vec![0],
            alloc: vec![micro],
            kp: 1,
        }],
        microbatch: micro,
        num_micro: 2,
    };

    let mut opts = TrainOpts {
        steps: 5,
        opt: OptimizerCfg::Sgd { lr: 0.05, momentum: 0.0 }, // no momentum: state is just weights
        seed: 3,
        log_every: 0,
        ..Default::default()
    };
    let mut data = LmTask::new(vocab, seq, micro, 3);
    let phase1 = train(&artifacts, "lm", &plan, &opts, &mut data).unwrap();
    assert_eq!(phase1.final_params.len(), nl, "checkpoint covers every layer");

    opts.initial_params = Some(std::sync::Arc::new(phase1.final_params.clone()));
    opts.steps = 3;
    let phase2 = train(&artifacts, "lm", &plan, &opts, &mut data).unwrap();

    // Continuous run over the same data stream for reference.
    let mut opts_ref = opts.clone();
    opts_ref.initial_params = None;
    opts_ref.steps = 8;
    let mut data_ref = LmTask::new(vocab, seq, micro, 3);
    let reference = train(&artifacts, "lm", &plan, &opts_ref, &mut data_ref).unwrap();

    for (i, (split, cont)) in phase1
        .losses
        .iter()
        .chain(&phase2.losses)
        .zip(&reference.losses)
        .enumerate()
    {
        assert!(
            (split - cont).abs() < 1e-3,
            "step {i}: split {split} vs continuous {cont}"
        );
    }
}
