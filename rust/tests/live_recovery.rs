//! Live fault-tolerance integration: a device exits mid-training and
//! the pipeline replays — real PJRT execution before and after, with
//! the checkpointed weights carried across the re-planning.

use std::path::PathBuf;

use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::coordinator::Coordinator;
use asteroid::data::LmTask;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::{OptimizerCfg, TrainOpts};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn training_survives_device_exit_with_warm_weights() {
    let artifacts = artifacts_dir();
    let manifest = Manifest::load(&artifacts).expect("run `make artifacts` first");
    let lm = manifest.model("lm").unwrap();
    let micro = lm.microbatch;
    let vocab = *lm.config.get("vocab").unwrap() as usize;
    let seq = *lm.config.get("seq").unwrap() as usize;

    // 3-device cluster so losing one still leaves a pipeline.
    let cluster = ClusterSpec::env("D", 1000.0).unwrap();
    let cfg = TrainConfig::new(micro * 4, micro);
    let c = Coordinator::for_artifact_model(&artifacts, "lm", cluster, cfg).unwrap();
    let plan = c.plan().unwrap().plan;
    assert!(plan.devices().len() >= 2, "need a multi-device plan");

    let opts = TrainOpts {
        steps: 0, // set per phase by train_with_failure
        opt: OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 },
        seed: 11,
        emulate: None,
        log_every: 0,
        initial_params: None,
    };
    let mut data = LmTask::new(vocab, seq, micro, 11);
    let failed = *plan.devices().last().unwrap();
    let (before, report, after) = c
        .train_with_failure(&plan, &opts, &mut data, 8, failed, 6)
        .unwrap();

    // The replayed pipeline excludes the failed device.
    assert!(!report.new_plan.devices().contains(&failed));

    // Loss must *continue*, not restart: the first post-recovery loss
    // stays close to the last pre-failure loss, far below a cold
    // restart at ln(V).
    let last_before = *before.losses.last().unwrap();
    let first_after = after.losses[0];
    let cold = (vocab as f64).ln();
    assert!(
        first_after < last_before + 0.4,
        "warm-start lost progress: {last_before} -> {first_after}"
    );
    assert!(
        first_after < cold - 0.5,
        "looks like a cold restart: {first_after} vs ln(V) = {cold}"
    );
    // ... and training keeps improving afterwards.
    let final_loss = *after.losses.last().unwrap();
    assert!(final_loss <= first_after + 0.05, "{first_after} -> {final_loss}");
}

#[test]
fn checkpoint_roundtrip_preserves_training_state() {
    // Train k steps, stop, warm-start a fresh pipeline from the final
    // weights: the loss must continue exactly as if uninterrupted.
    let artifacts = artifacts_dir();
    let manifest = Manifest::load(&artifacts).unwrap();
    let lm = manifest.model("lm").unwrap();
    let micro = lm.microbatch;
    let vocab = *lm.config.get("vocab").unwrap() as usize;
    let seq = *lm.config.get("seq").unwrap() as usize;
    let nl = lm.layers.len();

    let cluster = ClusterSpec::env("D", 1000.0).unwrap();
    let cfg = TrainConfig::new(micro * 2, micro);
    let c = Coordinator::for_artifact_model(&artifacts, "lm", cluster, cfg).unwrap();
    let plan = asteroid::planner::Plan {
        stages: vec![asteroid::planner::Stage {
            layers: (0, nl),
            devices: vec![0],
            alloc: vec![micro],
            kp: 1,
        }],
        microbatch: micro,
        num_micro: 2,
    };

    let mut opts = TrainOpts {
        steps: 5,
        opt: OptimizerCfg::Sgd { lr: 0.05, momentum: 0.0 }, // no momentum: state is just weights
        seed: 3,
        log_every: 0,
        ..Default::default()
    };
    let mut data = LmTask::new(vocab, seq, micro, 3);
    let phase1 = c.train(&plan, &opts, &mut data).unwrap();
    assert_eq!(phase1.final_params.len(), nl, "checkpoint covers every layer");

    opts.initial_params = Some(std::sync::Arc::new(phase1.final_params.clone()));
    opts.steps = 3;
    let phase2 = c.train(&plan, &opts, &mut data).unwrap();

    // Continuous run over the same data stream for reference.
    let mut opts_ref = opts.clone();
    opts_ref.initial_params = None;
    opts_ref.steps = 8;
    let mut data_ref = LmTask::new(vocab, seq, micro, 3);
    let reference = c.train(&plan, &opts_ref, &mut data_ref).unwrap();

    for (i, (split, cont)) in phase1
        .losses
        .iter()
        .chain(&phase2.losses)
        .zip(&reference.losses)
        .enumerate()
    {
        assert!(
            (split - cont).abs() < 1e-3,
            "step {i}: split {split} vs continuous {cont}"
        );
    }
}
