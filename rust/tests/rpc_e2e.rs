//! End-to-end tests of the multi-process RPC backend: real
//! `asteroid-worker` OS processes (spawned from the built binary),
//! real TCP transport, and a real mid-round process kill with
//! heartbeat-detected recovery.
//!
//! These are the in-repo versions of the CI `integration` job: tier-1
//! (`cargo test`) exercises process isolation too, not just CI.

use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use asteroid::comm::SyncMode;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::fault::{ChurnTrace, HeartbeatCfg};
use asteroid::planner::baselines::Method;
use asteroid::planner::Planner;
use asteroid::session::{ChurnSpec, FaultSpec, RecoveryKind, RpcBackend, Session};

/// A spawned worker process, killed on drop so a failing test never
/// leaks listeners.
struct Worker {
    child: Child,
    addr: String,
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker() -> Worker {
    spawn_worker_at("127.0.0.1:0")
}

fn spawn_worker_at(listen: &str) -> Worker {
    let mut child = Command::new(env!("CARGO_BIN_EXE_asteroid-worker"))
        .args(["--listen", listen, "--quiet"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning asteroid-worker");
    // The worker prints `listening on <addr>` once bound (port 0
    // resolved by the kernel, so parallel tests never collide).
    let stdout = child.stdout.take().expect("worker stdout");
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .expect("reading worker banner");
    let addr = line
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected worker banner {line:?}"))
        .to_string();
    Worker { child, addr }
}

/// 3 homogeneous devices, GPipe-PP planning (exactly one stage per
/// device — the canonical 3-process shape), tiny round.
fn three_stage_session() -> asteroid::session::SessionBuilder {
    Session::builder()
        .model("mobilenetv2")
        .cluster(ClusterSpec::env("nanos:3", 100.0).unwrap())
        .train(TrainConfig::new(8, 2))
        .planner(Planner::Baseline(Method::GpipePP))
        .steps(2)
        .log_every(0)
}

/// `n` homogeneous devices planned data-parallel: one stage replicated
/// `n` wide — every worker is a ring member, so the round sync is the
/// whole story.
fn replicated_session(n: usize) -> asteroid::session::SessionBuilder {
    Session::builder()
        .model("mobilenetv2")
        .cluster(ClusterSpec::env(&format!("nanos:{n}"), 100.0).unwrap())
        .train(TrainConfig::new(8, 2))
        .planner(Planner::Baseline(Method::DataParallel))
        .steps(2)
        .log_every(0)
}

#[test]
fn three_processes_train_two_rounds() {
    let workers: Vec<Worker> = (0..3).map(|_| spawn_worker()).collect();
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();

    let session = three_stage_session().build().unwrap();
    assert_eq!(session.plan().stages.len(), 3, "pp on 3 devices = 3 stages");

    let report = session.run(&mut RpcBackend::connect(addrs)).unwrap();
    assert_eq!(report.backend, "rpc");
    assert_eq!(report.rounds, 2);
    assert_eq!(report.losses.len(), 2);
    assert!(report.losses.iter().all(|l| l.is_finite() && *l > 0.0), "{:?}", report.losses);
    assert!(report.throughput > 0.0);
    assert!(report.recoveries.is_empty());
    // The checkpoint stream covers the whole model.
    let fp = report.final_params.as_ref().expect("rpc returns final params");
    assert_eq!(fp.len(), session.model().num_layers());
    // Per-device RPC telemetry: every worker beat and reported.
    let rpc = report.rpc.as_ref().expect("rpc stats");
    assert_eq!(rpc.per_device.len(), 3);
    for d in &rpc.per_device {
        assert_eq!(d.rounds_reported, 2, "device {}", d.device);
        assert!(d.bytes_tx > 0 && d.bytes_rx > 0, "device {}", d.device);
    }
    assert!(rpc.detection_wall_s.is_none());
}

#[test]
fn worker_process_kill_is_detected_and_replayed() {
    let mut workers: Vec<Worker> = (0..3).map(|_| spawn_worker()).collect();
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();

    let session = three_stage_session()
        .fault(
            FaultSpec::last_planned()
                .after(1)
                .resume_for(1)
                .with_heartbeat(HeartbeatCfg::tight()),
        )
        .build()
        .unwrap();
    // LastPlanned on a 3-stage chain = the head-stage device, which is
    // the third worker in stage-major address order.
    let failed_device = *session.plan().devices().last().unwrap();
    assert_eq!(failed_device, 2);

    let report = session.run(&mut RpcBackend::connect(addrs)).unwrap();
    assert_eq!(report.rounds, 2, "1 pre-fault + 1 resumed");
    assert_eq!(report.losses.len(), 2);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    assert_eq!(report.recoveries.len(), 1);
    let ev = &report.recoveries[0];
    assert_eq!(ev.round, 1);
    assert_eq!(ev.failed_device, failed_device);
    assert_eq!(ev.report.mechanism, "lightweight");
    assert!(!ev.report.new_plan.devices().contains(&failed_device));
    assert!(!ev.report.replay_micros.is_empty());
    // Live detection happened on the heartbeat clock, not a fluke:
    // wall-clock is at least the silence deadline and well under the
    // driver's timeouts.
    let rpc = report.rpc.as_ref().expect("rpc stats");
    let detect = rpc.detection_wall_s.expect("measured detection");
    assert!(detect < 10.0, "detection took {detect}s");

    // The killed worker really is a dead OS process (exit code 86),
    // not a live thread pretending.
    std::thread::sleep(Duration::from_millis(100));
    let status = workers[2]
        .child
        .try_wait()
        .expect("try_wait")
        .expect("killed worker should have exited");
    assert_eq!(status.code(), Some(86), "Die exits with the fault code");
    // Survivors got a clean Exit from the driver; Drop reaps them.
    drop(workers);
}

/// Elastic membership over real processes: a worker is killed by a
/// churn `exit` event, a fresh OS process rebinds the *same* port, and
/// the `join` event reconnects it — the driver re-Assigns everyone
/// against the re-expanded plan and training continues with warm-start
/// parameters from the driver checkpoint.
#[test]
fn killed_worker_restarts_and_rejoins_on_the_same_port() {
    let mut workers: Vec<Worker> = (0..3).map(|_| spawn_worker()).collect();
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();

    let trace = ChurnTrace::default().exit(1, 2).join(3, 2);
    let session = three_stage_session()
        .steps(4)
        .churn(ChurnSpec::from(trace).with_heartbeat(HeartbeatCfg::tight()))
        .build()
        .unwrap();
    assert_eq!(*session.plan().devices().last().unwrap(), 2);

    // Device 2 is the third worker in stage-major address order.  A
    // sidecar thread plays the "restarted edge device": it waits for
    // the churn exit to really kill the process, then launches a new
    // worker on the predecessor's port (the worker retries the bind
    // through TIME_WAIT; the driver retries the dial through the
    // restart window).
    let dead = workers.pop().unwrap();
    let respawn_addr = dead.addr.clone();
    let respawner = std::thread::spawn(move || {
        let mut dead = dead;
        let status = dead.child.wait().expect("waiting for churned worker");
        assert_eq!(status.code(), Some(86), "churn exit kills for real");
        spawn_worker_at(&respawn_addr)
    });

    let report = session.run(&mut RpcBackend::connect(addrs)).unwrap();
    let revived = respawner.join().expect("respawner thread");

    assert_eq!(report.rounds, 4, "churn events fire between rounds; none is lost");
    assert_eq!(report.losses.len(), 4);
    assert!(report.losses.iter().all(|l| l.is_finite()), "{:?}", report.losses);
    assert_eq!(report.recoveries.len(), 2, "one exit + one rejoin");

    let exit = &report.recoveries[0];
    assert_eq!(exit.round, 1);
    assert_eq!(exit.failed_device, 2);
    assert_eq!(exit.kind, RecoveryKind::HeavyIncremental);
    assert_eq!(exit.report.mechanism, "heavy-incremental");
    assert!(!exit.report.new_plan.devices().contains(&2));

    let rejoin = &report.recoveries[1];
    assert_eq!(rejoin.round, 3);
    assert_eq!(rejoin.failed_device, 2);
    assert_eq!(rejoin.kind, RecoveryKind::Rejoin);
    assert_eq!(rejoin.report.mechanism, "rejoin");
    assert!(
        rejoin.report.new_plan.devices().contains(&2),
        "the re-expanded plan must re-admit the rejoined device"
    );
    assert_eq!(rejoin.report.new_plan.devices().len(), 3, "full membership restored");
    assert!(rejoin.replan_wall_s >= 0.0);

    // Warm start: the driver checkpointed before the exit, so the run
    // still hands back a full final parameter set.
    let fp = report.final_params.as_ref().expect("rpc returns final params");
    assert_eq!(fp.len(), session.model().num_layers());

    // The survivors and the revived worker all got a clean Exit.
    drop(revived);
    drop(workers);
}

/// The tentpole invariant, live: a 4-wide replicated stage syncs
/// worker-to-worker under the default ring mode (the driver mediates
/// zero sync frames) and converges to the same losses as the
/// driver-star fallback within fp reduction-order tolerance.
#[test]
fn ring_sync_matches_driver_star_and_bypasses_the_driver() {
    let run = |mode: SyncMode| {
        let workers: Vec<Worker> = (0..4).map(|_| spawn_worker()).collect();
        let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();
        let session = replicated_session(4).sync(mode).build().unwrap();
        assert_eq!(session.plan().stages.len(), 1, "data-parallel = one stage");
        assert_eq!(session.plan().stages[0].devices.len(), 4, "replicated 4 wide");
        session.run(&mut RpcBackend::connect(addrs)).unwrap()
    };

    let ring = run(SyncMode::Ring);
    let star = run(SyncMode::DriverStar);

    // Same model, seed and data: the two collectives reduce the same
    // flats, differing only in fp summation order.
    assert_eq!(ring.losses.len(), 2);
    assert_eq!(star.losses.len(), 2);
    for (l_ring, l_star) in ring.losses.iter().zip(&star.losses) {
        assert!(l_ring.is_finite() && *l_ring > 0.0);
        let rel = (l_ring - l_star).abs() / l_star.abs().max(1e-12);
        assert!(rel < 1e-3, "ring {l_ring} vs star {l_star} (rel {rel})");
    }

    // Ring: the driver mediated nothing — O(1) control messages per
    // worker per round, zero sync frames; every member still moved
    // sync bytes (its 2(g-1)/g share, worker-metered).
    let rpc = ring.rpc.as_ref().expect("rpc stats");
    assert_eq!(rpc.sync_msgs, 0, "ring sync must bypass the driver");
    for d in &rpc.per_device {
        assert!(d.sync_bytes > 0, "device {} sent no ring chunks", d.device);
        assert!(d.sync_wall_s >= 0.0);
    }

    // Star: every member uploaded through the driver hub.
    let rpc = star.rpc.as_ref().expect("rpc stats");
    assert!(rpc.sync_msgs > 0, "driver-star sync is driver-mediated");
    for d in &rpc.per_device {
        assert!(d.sync_bytes > 0, "device {} uploaded no flat", d.device);
    }
    assert_eq!(ring.sync, SyncMode::Ring);
    assert_eq!(star.sync, SyncMode::DriverStar);
}

/// §3.4 fault path through the ring: a member dies mid-round, its
/// successor starves (or the heartbeat monitor fires first), the
/// driver aborts the round, and the ordinary recovery path replans and
/// resumes on the survivors — still syncing worker-to-worker.
#[test]
fn mid_ring_member_death_aborts_and_recovers() {
    let mut workers: Vec<Worker> = (0..3).map(|_| spawn_worker()).collect();
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();

    let session = replicated_session(3)
        .fault(
            FaultSpec::last_planned()
                .after(1)
                .resume_for(1)
                .with_heartbeat(HeartbeatCfg::tight()),
        )
        .build()
        .unwrap();
    // Last stage slot = last address = workers[2] (stage-major order).
    let failed_device = *session.plan().devices().last().unwrap();
    assert_eq!(failed_device, 2);

    let report = session.run(&mut RpcBackend::connect(addrs)).unwrap();
    assert_eq!(report.rounds, 2, "1 pre-fault + 1 resumed");
    assert!(report.losses.iter().all(|l| l.is_finite()), "{:?}", report.losses);
    assert_eq!(report.recoveries.len(), 1);
    let ev = &report.recoveries[0];
    assert_eq!(ev.failed_device, failed_device);
    assert!(!ev.report.new_plan.devices().contains(&failed_device));
    // The survivors re-formed a smaller ring and still synced without
    // the driver.
    let rpc = report.rpc.as_ref().expect("rpc stats");
    assert_eq!(rpc.sync_msgs, 0, "recovery must not fall back to driver sync");
    assert!(rpc.detection_wall_s.expect("measured detection") < 10.0);

    // The killed ring member really is a dead OS process.
    std::thread::sleep(Duration::from_millis(100));
    let status = workers[failed_device]
        .child
        .try_wait()
        .expect("try_wait")
        .expect("killed worker should have exited");
    assert_eq!(status.code(), Some(86), "Die exits with the fault code");
    drop(workers);
}
