//! The static verifier, attacked from both sides.
//!
//! Positive: every planner-produced (policy x cluster x codec x K_p)
//! schedule in the grid passes `verify::all` clean — the same grid the
//! CI `lint-ir` job runs through `asteroid lint` — plus a randomized
//! K_p-shrink property.
//!
//! Negative: seeded mutations of a known-clean schedule (drop a comm
//! edge, over-tag a version, shrink a budget, knock a transition out
//! of the protocol table...) must each trip the diagnostic code that
//! names the defect — every `Code` is provably reachable.

use asteroid::codec::CodecSpec;
use asteroid::comm::rpc::{DRIVER_TRANSITIONS, WORKER_TRANSITIONS};
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::schedule::{builtin_policies, policy_by_name, Payload, Schedule, Task};
use asteroid::session::Session;
use asteroid::util::bench::synthetic_fleet;
use asteroid::util::proptest::check;
use asteroid::verify::{self, protocol, Code, Diagnostic, Target};

fn session(env: &str, policy: &str, codec: &str) -> Session {
    session_on(ClusterSpec::env(env, 100.0).unwrap(), policy, codec)
}

fn session_on(cluster: ClusterSpec, policy: &str, codec: &str) -> Session {
    Session::builder()
        .model("mobilenetv2")
        .cluster(cluster)
        .train(TrainConfig::new(256, 16))
        .schedule(policy_by_name(policy).unwrap())
        .codec(CodecSpec::parse(codec).unwrap())
        .build()
        .unwrap()
}

fn show(diags: &[Diagnostic]) -> Vec<String> {
    diags.iter().map(|d| d.to_string()).collect()
}

/// Diagnostic codes for a session with a substituted schedule (how the
/// mutation tests inject a doctored IR).
fn codes(s: &Session, schedule: &Schedule) -> Vec<Code> {
    let t = Target {
        model: s.model(),
        cfg: s.train_config(),
        cluster: s.cluster(),
        plan: s.plan(),
        schedule,
        policy: s.policy(),
        codec: s.codec(),
    };
    verify::all(&t).into_iter().map(|d| d.code).collect()
}

fn assert_trips(s: &Session, schedule: &Schedule, code: Code) {
    let found = codes(s, schedule);
    assert!(found.contains(&code), "expected {} {:?}, got {found:?}", code.id(), code);
}

/// Index of the first timeline that actually computes (nonzero share
/// and at least one forward) — mutation targets must not land on an
/// idle replica slot.
fn busy(sched: &Schedule) -> usize {
    sched
        .timelines
        .iter()
        .position(|tl| tl.share > 0 && tl.tasks.iter().any(|t| matches!(t, Task::Fwd { .. })))
        .expect("a computing timeline")
}

// ------------------------------------------------------ positive grid

#[test]
fn grid_is_clean() {
    for env in ["B", "C"] {
        for policy in builtin_policies() {
            for codec in ["fp32", "int8"] {
                let s = session(env, policy.name(), codec);
                let diags = verify::all(&Target::of_session(&s));
                assert!(
                    diags.is_empty(),
                    "env {env} policy {} codec {codec}: {:?}",
                    policy.name(),
                    show(&diags)
                );
            }
        }
    }
}

#[test]
fn fleet_point_is_clean() {
    let s = session_on(synthetic_fleet(128, 100.0), "1f1b-kp", "int8");
    let diags = verify::all(&Target::of_session(&s));
    assert!(diags.is_empty(), "{:?}", show(&diags));
}

#[test]
fn override_on_real_cut_is_clean_and_applies() {
    let probe = session("C", "1f1b-kp", "int8");
    assert!(probe.plan().num_stages() > 1, "need a pipeline to cut");
    let cut = probe.plan().stages[0].layers.1;
    let s = session("C", "1f1b-kp", &format!("int8,{cut}=int8"));
    assert_eq!(s.codec().overrides().count(), 1);
    let diags = verify::all(&Target::of_session(&s));
    assert!(diags.is_empty(), "{:?}", show(&diags));
}

/// Random (env, policy, codec, K_p-shrink) points stay clean: K_p may
/// be shrunk below the planner's choice (never grown — growing can
/// legitimately exceed Eq. 3) and the rebuilt schedule must verify.
#[test]
fn shrunk_kp_schedules_verify_clean() {
    let envs = ["A", "B", "C", "D"];
    let policies = builtin_policies();
    let codecs = ["fp32", "fp16", "int8"];
    check(
        10,
        |rng| {
            let e = rng.below(envs.len());
            let p = rng.below(policies.len());
            let c = rng.below(codecs.len());
            (e, p, c, rng.next_u64())
        },
        |&(e, p, c, kp_seed)| {
            let s = session(envs[e], policies[p].name(), codecs[c]);
            let mut plan = s.plan().clone();
            for (i, st) in plan.stages.iter_mut().enumerate() {
                st.kp = 1 + (kp_seed as usize >> i) % st.kp.max(1);
            }
            let schedule = Schedule::for_sim(&plan, s.model(), s.policy());
            let t = Target {
                model: s.model(),
                cfg: s.train_config(),
                cluster: s.cluster(),
                plan: &plan,
                schedule: &schedule,
                policy: s.policy(),
                codec: s.codec(),
            };
            let diags = verify::all(&t);
            if diags.is_empty() {
                Ok(())
            } else {
                Err(format!("{:?}", show(&diags)))
            }
        },
    );
}

// ------------------------------------------- mutations, one per code

#[test]
fn astr001_held_back_activation_deadlocks() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut sched = s.schedule().clone();
    let tl = sched
        .timelines
        .iter_mut()
        .find(|tl| {
            let sends_act = tl
                .tasks
                .iter()
                .any(|t| matches!(t, Task::Send { payload: Payload::Activation, .. }));
            let recvs_grad = tl
                .tasks
                .iter()
                .any(|t| matches!(t, Task::Recv { payload: Payload::Gradient, .. }));
            sends_act && recvs_grad
        })
        .expect("a pipelined timeline");
    let si = tl
        .tasks
        .iter()
        .position(|t| matches!(t, Task::Send { payload: Payload::Activation, .. }))
        .unwrap();
    let ri = tl
        .tasks
        .iter()
        .position(|t| matches!(t, Task::Recv { payload: Payload::Gradient, .. }))
        .unwrap();
    assert!(si < ri, "the activation leaves before the gradient returns");
    // Move the first activation Send to just after the first gradient
    // Recv: this device now waits for a gradient its peer can only
    // produce after receiving the activation being held back.
    let send = tl.tasks.remove(si);
    tl.tasks.insert(ri, send);
    assert_trips(&s, &sched, Code::DeadlockCycle);
}

#[test]
fn astr002_shrunk_window_overflows_inflight() {
    let s = session("B", "gpipe-fill-drain", "fp32");
    let mut sched = s.schedule().clone();
    let i = busy(&sched);
    let tl = &mut sched.timelines[i];
    assert!(tl.kp > 1, "fill-drain holds the whole round in flight");
    tl.kp = 1;
    assert_trips(&s, &sched, Code::InflightWindow);
}

#[test]
fn astr003_bwd_before_fwd() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut sched = s.schedule().clone();
    let i = busy(&sched);
    let tl = &mut sched.timelines[i];
    let fi = tl.tasks.iter().position(|t| matches!(t, Task::Fwd { .. })).unwrap();
    let bi = tl.tasks.iter().position(|t| matches!(t, Task::Bwd { .. })).unwrap();
    tl.tasks.swap(fi, bi);
    assert_trips(&s, &sched, Code::OrderViolation);
}

#[test]
fn astr004_duplicate_forward() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut sched = s.schedule().clone();
    let i = busy(&sched);
    let tl = &mut sched.timelines[i];
    let fwd = *tl.tasks.iter().find(|t| matches!(t, Task::Fwd { .. })).unwrap();
    tl.tasks.push(fwd);
    assert_trips(&s, &sched, Code::DuplicateTask);
}

#[test]
fn astr005_dropped_send_leaves_orphan_recv() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut sched = s.schedule().clone();
    let tl = sched
        .timelines
        .iter_mut()
        .find(|tl| tl.tasks.iter().any(|t| matches!(t, Task::Send { .. })))
        .expect("a sending timeline");
    let si = tl.tasks.iter().position(|t| matches!(t, Task::Send { .. })).unwrap();
    tl.tasks.remove(si);
    assert_trips(&s, &sched, Code::CommMismatch);
}

#[test]
fn astr006_missing_backward() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut sched = s.schedule().clone();
    let i = busy(&sched);
    let tl = &mut sched.timelines[i];
    let bi = tl.tasks.iter().rposition(|t| matches!(t, Task::Bwd { .. })).unwrap();
    tl.tasks.remove(bi);
    assert_trips(&s, &sched, Code::CountMismatch);
}

#[test]
fn astr007_partial_split_backward() {
    let s = session("B", "zb-h1", "fp32");
    let mut sched = s.schedule().clone();
    let tl = sched
        .timelines
        .iter_mut()
        .find(|tl| tl.tasks.iter().filter(|t| matches!(t, Task::BwdW { .. })).count() >= 2)
        .expect("zero-bubble splits backwards");
    let wi = tl.tasks.iter().position(|t| matches!(t, Task::BwdW { .. })).unwrap();
    tl.tasks.remove(wi);
    assert_trips(&s, &sched, Code::PartialSplit);
}

#[test]
fn astr008_version_tag_under_sync_policy() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut sched = s.schedule().clone();
    let i = busy(&sched);
    let tl = &mut sched.timelines[i];
    let fi = tl.tasks.iter().position(|t| matches!(t, Task::Fwd { .. })).unwrap();
    if let Task::Fwd { version, .. } = &mut tl.tasks[fi] {
        *version = 1;
    }
    assert_trips(&s, &sched, Code::SyncNonzeroVersion);
}

#[test]
fn astr009_backward_reads_unstashed_version() {
    let s = session("B", "async:1", "fp32");
    let mut sched = s.schedule().clone();
    let i = busy(&sched);
    let tl = &mut sched.timelines[i];
    let bi = tl.tasks.iter().position(|t| matches!(t, Task::Bwd { .. })).unwrap();
    if let Task::Bwd { version, .. } = &mut tl.tasks[bi] {
        *version += 1;
    }
    assert_trips(&s, &sched, Code::VersionMismatch);
}

#[test]
fn astr010_staleness_window_shrunk_below_lag() {
    let s = session("B", "async:1", "fp32");
    let mut sched = s.schedule().clone();
    for tl in &mut sched.timelines {
        tl.kp = 1;
    }
    assert_trips(&s, &sched, Code::StalenessWindow);
}

#[test]
fn astr011_tiny_budget_overflows() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut cluster = s.cluster().clone();
    for d in &mut cluster.devices {
        d.mem_bytes = 1;
    }
    let t = Target {
        model: s.model(),
        cfg: s.train_config(),
        cluster: &cluster,
        plan: s.plan(),
        schedule: s.schedule(),
        policy: s.policy(),
        codec: s.codec(),
    };
    let found: Vec<Code> = verify::all(&t).into_iter().map(|d| d.code).collect();
    assert!(found.contains(&Code::MemoryBudget), "{found:?}");
}

#[test]
fn astr012_extra_stash_disagrees_with_planner() {
    let s = session("B", "1f1b-kp", "fp32");
    let mut sched = s.schedule().clone();
    let i = busy(&sched);
    sched.timelines[i].stash_copies += 2;
    assert_trips(&s, &sched, Code::MemoryDisagreement);
}

#[test]
fn astr013_knocked_out_transition_is_a_hole() {
    assert!(protocol::check().is_empty(), "live tables must be total");
    let found: Vec<Code> = protocol::check_tables(&WORKER_TRANSITIONS[1..], DRIVER_TRANSITIONS)
        .into_iter()
        .map(|d| d.code)
        .collect();
    assert!(found.contains(&Code::ProtocolHole), "{found:?}");
}

#[test]
fn astr014_inert_codec_override() {
    let s = session("B", "1f1b-kp", "fp32");
    let inert = CodecSpec::parse("fp32,999=int8").unwrap();
    let t = Target {
        model: s.model(),
        cfg: s.train_config(),
        cluster: s.cluster(),
        plan: s.plan(),
        schedule: s.schedule(),
        policy: s.policy(),
        codec: &inert,
    };
    let found: Vec<Code> = verify::all(&t).into_iter().map(|d| d.code).collect();
    assert!(found.contains(&Code::CodecOverride), "{found:?}");
}

// ----------------------------------------------- builder hard error

#[test]
fn builder_rejects_inert_codec_override() {
    let err = Session::builder()
        .model("mobilenetv2")
        .cluster(ClusterSpec::env("B", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .codec(CodecSpec::parse("fp32,999=int8").unwrap())
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("999") && err.contains("inert"), "{err}");
}
