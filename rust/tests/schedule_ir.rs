//! Schedule IR invariants across a plan grid, plus the exact
//! simulator/cost-model cross-check for homogeneous chains.
//!
//! The grid covers (stages x micros x K_p x staleness) for all five
//! built-in policies and both sharding modes; every generated timeline
//! must be dependency-valid (no Bwd before its Fwd, no Recv before the
//! matching Send, the K_p + staleness in-flight bound respected, weight
//! version tags consistent) and the whole schedule deadlock-free.

use asteroid::config::ClusterSpec;
use asteroid::model::{Layer, ModelDesc};
use asteroid::planner::cost::{plan_steps, round_latency};
use asteroid::planner::plan::{Plan, Stage};
use asteroid::profiler::ProfileTable;
use asteroid::schedule::{
    builtin_policies, diff, ComputeOp, GpipeFillDrain, OneFOneBKp, Schedule, SchedulePolicy,
    Task,
};
use asteroid::sim::simulate_round;

/// A model of `n` identical layers: equal splits give *exactly* equal
/// stage costs on a homogeneous cluster, which is what makes the
/// dominant-step model exact (see `sim_matches_analytic_*`).
fn uniform_model(n: usize) -> ModelDesc {
    let layers = (0..n)
        .map(|i| Layer::new(&format!("u{i}"), 1.0e9, 64 * 1024, 16 * 1024))
        .collect();
    ModelDesc::new("uniform", layers, 16 * 1024)
}

/// A chain plan: `stages` single-device stages over an equal layer
/// split, one device per stage, full micro-batch per device.
fn chain_plan(model: &ModelDesc, stages: usize, microbatch: usize, num_micro: usize) -> Plan {
    let nl = model.num_layers();
    assert_eq!(nl % stages, 0, "uniform split required");
    let per = nl / stages;
    let mut plan = Plan {
        stages: (0..stages)
            .map(|s| Stage {
                layers: (s * per, (s + 1) * per),
                devices: vec![s],
                alloc: vec![microbatch],
                kp: 1,
            })
            .collect(),
        microbatch,
        num_micro,
    };
    plan.apply_default_kp();
    plan
}

#[test]
fn task_lists_dependency_valid_across_grid() {
    let model = uniform_model(24);
    let policies: [&'static dyn SchedulePolicy; 5] = builtin_policies();
    for &stages in &[1usize, 2, 3, 4] {
        for &m in &[1usize, 2, 4, 8] {
            for &kp_override in &[0usize, 1, 2, m] {
                let mut plan = chain_plan(&model, stages, 4, m);
                if kp_override > 0 {
                    for s in &mut plan.stages {
                        s.kp = kp_override.clamp(1, m);
                    }
                }
                for policy in policies {
                    let sim_sched = Schedule::for_sim(&plan, &model, policy);
                    sim_sched
                        .validate()
                        .unwrap_or_else(|e| panic!(
                            "sim schedule invalid (stages={stages}, m={m}, \
                             kp={kp_override}, policy={}): {e}",
                            policy.name()
                        ));
                    let rt_sched = Schedule::for_runtime(&plan, policy);
                    rt_sched
                        .validate()
                        .unwrap_or_else(|e| panic!(
                            "runtime schedule invalid (stages={stages}, m={m}, \
                             kp={kp_override}, policy={}): {e}",
                            policy.name()
                        ));
                    // Every device forwards and backwards each micro
                    // exactly once across the stage (sim sharding).
                    for tl in &sim_sched.timelines {
                        assert_eq!(tl.num_fwd(), m);
                    }
                }
            }
        }
    }
}

#[test]
fn grid_includes_replicated_stages() {
    // Sample-shard routing with a 2-device group: overlap-derived
    // Send/Recv fan-out must still validate for both policies.
    let model = uniform_model(24);
    let cluster = ClusterSpec::nanos(3, 100.0);
    assert_eq!(cluster.n(), 3);
    for &m in &[2usize, 4, 8] {
        let mut plan = Plan {
            stages: vec![
                Stage { layers: (0, 12), devices: vec![0, 1], alloc: vec![3, 1], kp: 1 },
                Stage { layers: (12, 24), devices: vec![2], alloc: vec![4], kp: 1 },
            ],
            microbatch: 4,
            num_micro: m,
        };
        plan.apply_default_kp();
        for policy in builtin_policies() {
            Schedule::for_sim(&plan, &model, policy).validate().unwrap();
            Schedule::for_runtime(&plan, policy).validate().unwrap();
        }
    }
}

/// Satellite property: for every policy over an (n_micros × K_p) grid,
/// the emitted order's in-flight activation peak equals exactly what
/// `effective_kp` promises — the value Eq. 3 memory accounting charges.
#[test]
fn inflight_peak_equals_effective_kp_for_every_policy() {
    for policy in builtin_policies() {
        for n in [1usize, 2, 3, 5, 8, 13] {
            for kp in 1..=(n + 2) {
                let micros: Vec<usize> = (0..n).collect();
                let ops = policy.compute_order(&micros, kp);
                let mut cur = 0usize;
                let mut peak = 0usize;
                for op in &ops {
                    match op {
                        ComputeOp::Fwd(_) => {
                            cur += 1;
                            peak = peak.max(cur);
                        }
                        ComputeOp::Bwd(_) => cur -= 1,
                        ComputeOp::BwdW(_) => {}
                    }
                }
                assert_eq!(
                    peak,
                    policy.effective_kp(kp, n),
                    "{}: n={n} kp={kp}",
                    policy.name()
                );
            }
        }
    }
}

/// Satellite property: over every policy × (n_micros, K_p, staleness)
/// grid point, no task observes a weight version older than the
/// policy's `max_staleness` bound — i.e. the admission window never
/// runs more than σ forwards ahead of the policy's synchronous
/// frontier (`effective_kp − max_staleness`), no backward applies a
/// gradient computed outside the stash window — and the in-flight
/// peak still equals exactly `effective_kp` (the value Eq. 3 charges).
#[test]
fn staleness_bound_and_inflight_peak_across_policy_grid() {
    use asteroid::schedule::policy_by_name;
    let mut policies: Vec<&'static dyn SchedulePolicy> = builtin_policies().to_vec();
    for sigma in [0usize, 2, 3] {
        policies.push(policy_by_name(&format!("async:{sigma}")).unwrap());
    }
    for policy in policies {
        let sigma = policy.max_staleness();
        for n in [1usize, 2, 3, 5, 8, 13] {
            for kp in 1..=(n + 2) {
                let micros: Vec<usize> = (0..n).collect();
                let ops = policy.compute_order(&micros, kp);
                let window = policy.effective_kp(kp, n);
                let sync_frontier = window - sigma.min(window - 1);
                let mut inflight = 0usize;
                let mut peak = 0usize;
                let mut updates = 0usize; // one per Bwd under σ > 0
                let mut read_at: std::collections::HashMap<usize, usize> =
                    std::collections::HashMap::new();
                for op in &ops {
                    match op {
                        ComputeOp::Fwd(m) => {
                            inflight += 1;
                            peak = peak.max(inflight);
                            read_at.insert(*m, updates);
                            // Staleness: forwards admitted beyond the
                            // synchronous frontier never exceed σ.
                            let ahead = inflight.saturating_sub(sync_frontier);
                            assert!(
                                ahead <= sigma,
                                "{}: n={n} kp={kp}: Fwd({m}) is {ahead} updates \
                                 beyond the sync frontier (σ = {sigma})",
                                policy.name()
                            );
                        }
                        ComputeOp::Bwd(m) => {
                            inflight -= 1;
                            if sigma > 0 {
                                // Weight stashing: the gradient applied
                                // now was computed inside the window.
                                let lag = updates - read_at[m];
                                assert!(
                                    lag < window,
                                    "{}: n={n} kp={kp}: Bwd({m}) lag {lag}",
                                    policy.name()
                                );
                                updates += 1;
                            }
                        }
                        ComputeOp::BwdW(_) => {}
                    }
                }
                assert_eq!(
                    peak,
                    window,
                    "{}: n={n} kp={kp}: in-flight peak != effective_kp",
                    policy.name()
                );
            }
        }
    }
}

/// Satellite property: `schedule::diff` of a policy with itself is
/// empty — recovery machinery never replays or retasks anything when
/// the schedule did not change, whatever the policy.
#[test]
fn diff_of_policy_with_itself_is_empty() {
    let model = uniform_model(24);
    for policy in builtin_policies() {
        for &m in &[2usize, 4, 8] {
            let plan = chain_plan(&model, 3, 4, m);
            let a = Schedule::for_sim(&plan, &model, policy);
            let b = Schedule::for_sim(&plan, &model, policy);
            let d = diff(&a, &b);
            assert!(
                d.removed.is_empty()
                    && d.added.is_empty()
                    && d.retasked.is_empty()
                    && d.replay_micros.is_empty(),
                "{}: m={m}",
                policy.name()
            );
            assert_eq!(d.unchanged.len(), a.timelines.len());
            // Same for the runtime sharding the replay path diffs.
            let ra = Schedule::for_runtime(&plan, policy);
            let rb = Schedule::for_runtime(&plan, policy);
            let rd = diff(&ra, &rb);
            assert!(rd.retasked.is_empty() && rd.replay_micros.is_empty());
        }
    }
}

#[test]
fn kp_bound_is_respected_not_just_recorded() {
    // Re-derive the in-flight peak straight from the task stream and
    // compare against the plan's K_p (1F1B) or M (GPipe).
    let model = uniform_model(24);
    let m = 8;
    let plan = chain_plan(&model, 2, 4, m); // kp = [3, 1]
    let sched = Schedule::for_sim(&plan, &model, &OneFOneBKp);
    for tl in &sched.timelines {
        let mut cur = 0usize;
        let mut peak = 0usize;
        for t in &tl.tasks {
            match t {
                Task::Fwd { .. } => {
                    cur += 1;
                    peak = peak.max(cur);
                }
                Task::Bwd { .. } => cur -= 1,
                _ => {}
            }
        }
        assert_eq!(peak, plan.stages[tl.stage].kp.min(m), "stage {}", tl.stage);
    }
    let gpipe = Schedule::for_sim(&plan, &model, &GpipeFillDrain);
    for tl in &gpipe.timelines {
        assert_eq!(tl.kp, m);
    }
}

/// Satellite cross-check: for single-stage and two-stage homogeneous
/// plans the event-accurate simulator must reproduce the analytic
/// `round_latency` (Eqs. 4-6) *exactly* (to f64 round-off) — this is
/// the regime where the dominant-step model is not an approximation,
/// so any drift between the two implementations is a bug in one of
/// them.
fn assert_sim_matches_analytic(cluster: &ClusterSpec, model: &ModelDesc, plan: &Plan) {
    let table = ProfileTable::new(cluster, model);
    let steps = plan_steps(&table, cluster, model, plan);
    let predicted = round_latency(&steps, plan.num_micro);
    let sim = simulate_round(&table, cluster, model, plan);
    let rel = (sim.round_latency - predicted).abs() / predicted.max(1e-30);
    assert!(
        rel < 1e-9,
        "sim {} vs analytic {predicted} (rel err {rel:.3e}) for {} stages",
        sim.round_latency,
        plan.num_stages()
    );
}

#[test]
fn sim_matches_analytic_single_stage_single_device() {
    let model = uniform_model(8);
    let cluster = ClusterSpec::nanos(1, 1000.0);
    let plan = Plan {
        stages: vec![Stage { layers: (0, 8), devices: vec![0], alloc: vec![8], kp: 1 }],
        microbatch: 8,
        num_micro: 8,
    };
    assert_sim_matches_analytic(&cluster, &model, &plan);
}

#[test]
fn sim_matches_analytic_single_stage_dp_group() {
    // Two-device DP group: adds the ring-AllReduce term of Eq. 5.
    let model = uniform_model(8);
    let cluster = ClusterSpec::nanos(2, 1000.0);
    let plan = Plan {
        stages: vec![Stage { layers: (0, 8), devices: vec![0, 1], alloc: vec![4, 4], kp: 1 }],
        microbatch: 8,
        num_micro: 8,
    };
    assert_sim_matches_analytic(&cluster, &model, &plan);
}

#[test]
fn sim_matches_analytic_two_stage_homogeneous() {
    // Equal-cost stages on identical devices with compute >> comm:
    // the dominant step is the tail stage and Eq. 6's shifting is
    // exact.  10 Gbps keeps 2 x comm far below one micro's FP+BP.
    let model = uniform_model(8);
    let cluster = ClusterSpec::nanos(2, 10000.0);
    let plan = chain_plan(&model, 2, 8, 8); // kp = [3, 1]
    assert_sim_matches_analytic(&cluster, &model, &plan);
}

#[test]
fn sim_matches_analytic_two_stage_across_micro_counts() {
    let model = uniform_model(8);
    let cluster = ClusterSpec::nanos(2, 10000.0);
    for m in [4usize, 8, 16, 32] {
        let plan = chain_plan(&model, 2, 8, m);
        assert_sim_matches_analytic(&cluster, &model, &plan);
    }
}
