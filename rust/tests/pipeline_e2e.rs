//! Integration tests: the real PJRT pipeline engine end-to-end.
//!
//! These run the actual AOT artifacts (built by `make artifacts`)
//! through multi-threaded HPP training and check the numerics: losses
//! start near ln(V) and fall, stage partitioning is transparent, and
//! replicated stages produce the same math as single-device stages.
//! They exercise the live engine directly on hand-built plans, so they
//! need a `--features pjrt` build with a real xla binding.
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use asteroid::data::LmTask;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::{train, OptimizerCfg, TrainOpts};
use asteroid::planner::plan::{Plan, Stage};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn lm_cfg() -> (usize, usize, usize) {
    let manifest = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
    let lm = manifest.model("lm").unwrap();
    let vocab = lm.cfg_usize("vocab").unwrap();
    let seq = lm.cfg_usize("seq").unwrap();
    (vocab, seq, lm.microbatch)
}

fn lm_layer_count() -> usize {
    let manifest = Manifest::load(&artifacts_dir()).unwrap();
    manifest.model("lm").unwrap().layers.len()
}

fn opts(steps: usize) -> TrainOpts {
    TrainOpts {
        steps,
        opt: OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 },
        seed: 7,
        emulate: None,
        log_every: 0,
        ..Default::default()
    }
}

/// Single-stage (single-device) training: the baseline numerics.
#[test]
fn lm_single_stage_loss_decreases() {
    let (vocab, seq, micro) = lm_cfg();
    let nl = lm_layer_count();
    let plan = Plan {
        stages: vec![Stage { layers: (0, nl), devices: vec![0], alloc: vec![micro], kp: 1 }],
        microbatch: micro,
        num_micro: 4,
    };
    let mut data = LmTask::new(vocab, seq, micro, 1);
    let stats = train(&artifacts_dir(), "lm", &plan, &opts(12), &mut data).unwrap();
    let first = stats.losses[0];
    let last = *stats.losses.last().unwrap();
    // Initial loss ~ ln(vocab); training must make clear progress (the
    // full convergence curve is exercised by examples/e2e_train_lm).
    assert!(
        (first - (vocab as f64).ln()).abs() < 1.0,
        "first loss {first} vs ln({vocab}) = {}",
        (vocab as f64).ln()
    );
    assert!(last < first - 0.25, "no progress: {first} -> {last}");
}

/// 2-stage pipeline must produce the same loss trajectory as single
/// stage (same seeds, same data): partitioning is numerically
/// transparent.
#[test]
fn lm_pipeline_matches_single_stage() {
    let (vocab, seq, micro) = lm_cfg();
    let nl = lm_layer_count();
    let single = Plan {
        stages: vec![Stage { layers: (0, nl), devices: vec![0], alloc: vec![micro], kp: 1 }],
        microbatch: micro,
        num_micro: 4,
    };
    let cut = nl / 2;
    let mut piped = Plan {
        stages: vec![
            Stage { layers: (0, cut), devices: vec![0], alloc: vec![micro], kp: 1 },
            Stage { layers: (cut, nl), devices: vec![1], alloc: vec![micro], kp: 1 },
        ],
        microbatch: micro,
        num_micro: 4,
    };
    piped.apply_default_kp();

    let mut d1 = LmTask::new(vocab, seq, micro, 99);
    let s1 = train(&artifacts_dir(), "lm", &single, &opts(4), &mut d1).unwrap();
    let mut d2 = LmTask::new(vocab, seq, micro, 99);
    let s2 = train(&artifacts_dir(), "lm", &piped, &opts(4), &mut d2).unwrap();

    for (a, b) in s1.losses.iter().zip(&s2.losses) {
        assert!(
            (a - b).abs() < 1e-3,
            "loss divergence: single {a} vs piped {b}"
        );
    }
}

/// Replicated first stage (intra-stage DP) must also match the
/// single-device trajectory: round-robin micro-batch DP + AllReduce is
/// numerically equivalent to serial gradient accumulation.
#[test]
fn lm_replicated_stage_matches_single_stage() {
    let (vocab, seq, micro) = lm_cfg();
    let nl = lm_layer_count();
    let single = Plan {
        stages: vec![Stage { layers: (0, nl), devices: vec![0], alloc: vec![micro], kp: 1 }],
        microbatch: micro,
        num_micro: 4,
    };
    let cut = nl / 2;
    let hybrid = Plan {
        stages: vec![
            Stage {
                layers: (0, cut),
                devices: vec![0, 1],
                alloc: vec![micro / 2, micro - micro / 2],
                kp: 3,
            },
            Stage { layers: (cut, nl), devices: vec![2], alloc: vec![micro], kp: 1 },
        ],
        microbatch: micro,
        num_micro: 4,
    };

    let mut d1 = LmTask::new(vocab, seq, micro, 5);
    let s1 = train(&artifacts_dir(), "lm", &single, &opts(3), &mut d1).unwrap();
    let mut d2 = LmTask::new(vocab, seq, micro, 5);
    let s2 = train(&artifacts_dir(), "lm", &hybrid, &opts(3), &mut d2).unwrap();
    for (a, b) in s1.losses.iter().zip(&s2.losses) {
        assert!((a - b).abs() < 1e-3, "single {a} vs hybrid-DP {b}");
    }
}

/// Bandwidth emulation slows the same plan down.
#[test]
fn emulated_network_slows_training() {
    use asteroid::config::ClusterSpec;
    let (vocab, seq, micro) = lm_cfg();
    let nl = lm_layer_count();
    let cut = nl / 2;
    let mk = || Plan {
        stages: vec![
            Stage { layers: (0, cut), devices: vec![0], alloc: vec![micro], kp: 3 },
            Stage { layers: (cut, nl), devices: vec![1], alloc: vec![micro], kp: 1 },
        ],
        microbatch: micro,
        num_micro: 4,
    };

    let mut d1 = LmTask::new(vocab, seq, micro, 3);
    let fast = train(&artifacts_dir(), "lm", &mk(), &opts(3), &mut d1).unwrap();

    let mut slow_opts = opts(3);
    slow_opts.emulate = Some(ClusterSpec::nanos(2, 20.0)); // 2.5 MB/s links
    let mut d2 = LmTask::new(vocab, seq, micro, 3);
    let slow = train(&artifacts_dir(), "lm", &mk(), &slow_opts, &mut d2).unwrap();

    assert!(
        slow.samples_per_sec < fast.samples_per_sec,
        "emulated {} vs real {}",
        slow.samples_per_sec,
        fast.samples_per_sec
    );
    // Numerics must be unaffected by shaping.
    for (a, b) in fast.losses.iter().zip(&slow.losses) {
        assert!((a - b).abs() < 1e-3);
    }
}
