//! Integration tests for the unified plan→execute surface: builder
//! validation, Asteroid-vs-baseline parity through the one `Planner`
//! dispatch, `FaultSpec`-driven recovery, and sim-vs-live `RunReport`
//! structural parity.

use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::planner::baselines::{self, Method};
use asteroid::planner::{AllocOpts, Planner, PlannerConfig};
use asteroid::profiler::ProfileTable;
use asteroid::schedule::{GpipeFillDrain, Task, ZeroBubbleH1, DEFAULT_POLICY};
use asteroid::session::{FaultSpec, Session, SimBackend};

fn builder(env: &str) -> asteroid::session::SessionBuilder {
    Session::builder()
        .model("mobilenetv2")
        .cluster(ClusterSpec::env(env, 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
}

// ----------------------------------------------------------- builder

#[test]
fn builder_validation_errors_name_the_missing_piece() {
    let err = Session::builder().build().unwrap_err().to_string();
    assert!(err.contains(".model"), "{err}");

    let err = Session::builder()
        .model("mobilenetv2")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains(".cluster"), "{err}");

    let err = Session::builder()
        .model("mobilenetv2")
        .cluster(ClusterSpec::env("B", 100.0).unwrap())
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.to_lowercase().contains("train"), "{err}");

    let err = Session::builder()
        .model("not-a-model")
        .cluster(ClusterSpec::env("B", 100.0).unwrap())
        .train(TrainConfig::new(64, 8))
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("not-a-model"), "{err}");
}

#[test]
fn missing_artifacts_fail_at_build_not_at_run() {
    let err = Session::builder()
        .artifact_model("definitely/not/a/dir", "lm")
        .cluster(ClusterSpec::env("B", 100.0).unwrap())
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
}

// ------------------------------------------- planner dispatch parity

/// Each baseline `Method` planned through the unified `Planner` path
/// must match the dedicated planner function it folded in.
#[test]
fn unified_dispatch_matches_legacy_planner_functions() {
    let cluster = ClusterSpec::env("C", 100.0).unwrap();
    let model = asteroid::model::zoo::mobilenet_v2();
    let table = ProfileTable::new(&cluster, &model);
    let cfg = TrainConfig::new(256, 16);

    let legacy: Vec<(Method, asteroid::planner::Plan)> = vec![
        (
            Method::DataParallel,
            baselines::plan_dp(&table, &cluster, &model, &cfg, AllocOpts::default(), DEFAULT_POLICY)
                .unwrap()
                .plan,
        ),
        (
            Method::Eddl,
            baselines::plan_dp(&table, &cluster, &model, &cfg, AllocOpts::default(), DEFAULT_POLICY)
                .unwrap()
                .plan,
        ),
        (
            Method::GpipePP,
            baselines::plan_gpipe_pp(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
                .unwrap()
                .plan,
        ),
        (
            Method::PipeDream,
            baselines::plan_pipedream(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
                .unwrap()
                .plan,
        ),
        (
            Method::Dapple,
            baselines::plan_dapple(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
                .unwrap()
                .plan,
        ),
    ];
    for (m, expected) in legacy {
        let s = Session::builder()
            .model("mobilenetv2")
            .cluster(cluster.clone())
            .train(cfg.clone())
            .planner(Planner::Baseline(m))
            .build()
            .unwrap();
        assert_eq!(s.plan(), &expected, "{m} diverged from its legacy planner");
    }

    // Asteroid == Custom(default config) == Baseline(Asteroid).
    let a = Planner::Asteroid
        .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
        .unwrap()
        .plan;
    let b = Planner::Baseline(Method::Asteroid)
        .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
        .unwrap()
        .plan;
    let c = Planner::Custom(PlannerConfig::default())
        .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
        .unwrap()
        .plan;
    assert_eq!(a, b);
    assert_eq!(a, c);
}

#[test]
fn hetpipe_is_rejected_with_a_pointer_to_hdp() {
    let err = builder("B")
        .planner(Planner::Baseline(Method::HetPipe))
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("plan_hetpipe"), "{err:#}");
}

#[test]
fn method_cli_round_trip() {
    for m in Method::ALL {
        assert_eq!(m.to_string().to_ascii_lowercase().parse::<Method>().unwrap(), m);
    }
}

// ------------------------------------------------------ sim backend

#[test]
fn sim_report_is_fully_populated() {
    let s = builder("B").steps(6).build().unwrap();
    let report = s.run(&mut SimBackend::default()).unwrap();
    assert_eq!(report.backend, "sim");
    assert_eq!(report.rounds, 6);
    assert_eq!(report.round_secs.len(), 6);
    assert!(report.losses.is_empty(), "pricing has no numerics");
    assert!(report.throughput > 0.0);
    if report.plan.devices().len() > 1 {
        assert!(report.bytes_on_network > 0);
    }
    let sim = report.sim.as_ref().expect("sim detail");
    assert!(sim.round_latency > 0.0);
    assert_eq!(&report.plan, s.plan());
    assert_eq!(report.schedule.policy, s.schedule().policy);
    assert!(report.recoveries.is_empty());
    assert!(report.final_params.is_none());
}

#[test]
fn schedule_policy_is_a_session_property() {
    // The policy now governs *planning* as well as pricing: a
    // fill-drain session's memory budgets charge O(M) residency, so
    // its plan may legitimately differ from the 1F1B session's — what
    // must hold is that each session plans, validates and executes
    // under its own policy end-to-end.
    // Small round (M = 4) so fill-drain's O(M) residency fits env D
    // comfortably — the point is the threading, not an OOM corner.
    let mk = |env: &str| {
        Session::builder()
            .model("mobilenetv2")
            .cluster(ClusterSpec::env(env, 100.0).unwrap())
            .train(TrainConfig::new(64, 16))
    };
    let one = mk("D").build().unwrap();
    let gpipe = mk("D").schedule(&GpipeFillDrain).build().unwrap();
    assert_ne!(one.schedule().policy, gpipe.schedule().policy);
    assert_eq!(gpipe.schedule().policy, "gpipe-fill-drain");
    assert_eq!(gpipe.outcome().policy.name(), "gpipe-fill-drain");
    gpipe.schedule().validate().unwrap();
    // Every timeline of the fill-drain schedule buffers its whole load.
    for tl in &gpipe.schedule().timelines {
        assert_eq!(tl.kp, gpipe.plan().num_micro);
    }
    let t_one = one.run(&mut SimBackend::default()).unwrap();
    let t_gp = gpipe.run(&mut SimBackend::default()).unwrap();
    assert!(t_one.throughput > 0.0 && t_gp.throughput > 0.0);
}

#[test]
fn zero_bubble_session_plans_executes_and_replays_end_to_end() {
    // Acceptance check: `.schedule(&ZeroBubbleH1)` governs planning,
    // sim execution and fault replay — no DEFAULT_POLICY fallback
    // anywhere on the path.
    let zb = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .schedule(&ZeroBubbleH1)
        .steps(6)
        .fault(FaultSpec::last_planned().after(3))
        .build()
        .unwrap();
    assert_eq!(zb.schedule().policy, "zb-h1");
    assert_eq!(zb.outcome().schedule.policy, "zb-h1");
    // The planned schedule really is split-backward: one BwdW per Bwd.
    let n_bwd: usize = zb
        .schedule()
        .timelines
        .iter()
        .flat_map(|tl| tl.tasks.iter())
        .filter(|t| matches!(t, Task::Bwd { .. }))
        .count();
    let n_bww: usize = zb
        .schedule()
        .timelines
        .iter()
        .flat_map(|tl| tl.tasks.iter())
        .filter(|t| matches!(t, Task::BwdW { .. }))
        .count();
    assert!(n_bwd > 0);
    assert_eq!(n_bwd, n_bww);

    let report = zb.run(&mut SimBackend::default()).unwrap();
    assert_eq!(report.schedule.policy, "zb-h1");
    assert!(report.throughput > 0.0);
    // The fault replay diffed zb-h1 timelines and priced the recovered
    // round under zb-h1.
    assert_eq!(report.recoveries.len(), 1);
    let r = &report.recoveries[0].report;
    assert!(!r.replay_micros.is_empty());
    assert!(r.new_throughput > 0.0 && r.refill_s > 0.0);
}

#[test]
fn async_session_plans_prices_and_replays_end_to_end() {
    // Acceptance check for the bounded-staleness policy: selectable via
    // `.schedule(policy_by_name("async:<s>"))`, planned with
    // stash-aware budgets, priced at its steady state, recovered with
    // the full in-flight window — and the staleness fields surface in
    // the RunReport.
    use asteroid::schedule::policy_by_name;
    let policy = policy_by_name("async:2").unwrap();
    let s = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .schedule(policy)
        .steps(6)
        .fault(FaultSpec::last_planned().after(3))
        .build()
        .unwrap();
    assert_eq!(s.schedule().policy, "async:2");
    assert_eq!(s.schedule().max_staleness, 2);
    s.schedule().validate().unwrap();
    let report = s.run(&mut SimBackend::default()).unwrap();
    assert_eq!(report.max_staleness, 2);
    assert!(report.weight_stash_slots > 1, "window must exceed the live copy");
    let sim = report.sim.as_ref().unwrap();
    assert_eq!(sim.rounds_priced, asteroid::sim::ASYNC_STEADY_ROUNDS);
    assert!(report.throughput > 0.0);
    assert_eq!(report.recoveries.len(), 1);
    assert!(!report.recoveries[0].report.replay_micros.is_empty());

    // A synchronous session reports no staleness and single-round
    // pricing.
    let sync = builder("B").steps(2).build().unwrap();
    let sync_report = sync.run(&mut SimBackend::default()).unwrap();
    assert_eq!(sync_report.max_staleness, 0);
    assert_eq!(sync_report.weight_stash_slots, 1);
    assert_eq!(sync_report.sim.as_ref().unwrap().rounds_priced, 1);
}

// ------------------------------------------------- fault via FaultSpec

#[test]
fn fault_spec_replaces_bespoke_recovery_entry_points() {
    let base = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .steps(10)
        .build()
        .unwrap();
    let failed = *base.plan().devices().last().unwrap();

    let lite = base
        .clone()
        .with_fault(FaultSpec::device(failed).after(4))
        .run(&mut SimBackend::default())
        .unwrap();
    let heavy = base
        .clone()
        .with_fault(FaultSpec::device(failed).after(4).heavy())
        .run(&mut SimBackend::default())
        .unwrap();

    let (l, h) = (&lite.recoveries[0], &heavy.recoveries[0]);
    assert_eq!(l.round, 4);
    assert_eq!(l.failed_device, failed);
    assert_eq!(l.report.mechanism, "lightweight");
    assert_eq!(h.report.mechanism, "heavy");
    // Fig. 16/17 headline, through the declarative surface.
    assert!(
        h.report.total_s() > 2.0 * l.report.total_s(),
        "heavy {} vs lite {}",
        h.report.total_s(),
        l.report.total_s()
    );
    assert!(!l.report.new_plan.devices().contains(&failed));
    // Replay ordering comes from the schedule diff.
    assert!(!l.report.replay_micros.is_empty());
    assert!(l.report.refill_s > 0.0);

    // A fault target outside the plan is a validation error.
    assert!(base
        .with_fault(FaultSpec::device(4096))
        .run(&mut SimBackend::default())
        .is_err());
}

// ---------------------------------------------- sim-vs-live parity

/// `RpcBackend` and `SimBackend` must produce structurally identical
/// `RunReport`s for the same plan: same plan, same schedule policy,
/// same round count — the backend only changes whether rounds are
/// priced or executed over real sockets.  The workers here are serve
/// loops on threads (real TCP via loopback, one process); the
/// process-isolation flavour lives in `tests/rpc_e2e.rs`.
#[test]
fn rpc_and_sim_reports_share_structure() {
    use asteroid::pipeline::rpc_worker::{serve, ServeOpts, ServeOutcome};
    use asteroid::session::RpcBackend;
    use std::net::TcpListener;

    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..3 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve(listener, ServeOpts { die_for_real: false, verbose: false })
        }));
    }

    let session = Session::builder()
        .model("mobilenetv2")
        .cluster(ClusterSpec::env("nanos:3", 100.0).unwrap())
        .train(TrainConfig::new(8, 2))
        .planner(Planner::Baseline(Method::GpipePP))
        .steps(2)
        .log_every(0)
        .build()
        .unwrap();
    assert_eq!(session.plan().stages.len(), 3);

    let sim = session.run(&mut SimBackend::default()).unwrap();
    let live = session.run(&mut RpcBackend::connect(addrs)).unwrap();

    assert_eq!(sim.plan, live.plan);
    assert_eq!(sim.schedule.policy, live.schedule.policy);
    assert_eq!(sim.rounds, live.rounds);
    assert_eq!(sim.round_secs.len(), live.round_secs.len());
    assert_eq!(sim.predicted_throughput, live.predicted_throughput);
    assert_eq!(sim.max_staleness, live.max_staleness);
    assert_eq!(sim.weight_stash_slots, live.weight_stash_slots);
    assert!(sim.throughput > 0.0 && live.throughput > 0.0);
    // Backend-specific halves: pricing has detail but no numerics or
    // transport; the RPC run has numerics, the checkpoint and the
    // per-device transport meters, but no pricing.
    assert!(sim.sim.is_some() && sim.losses.is_empty() && sim.final_params.is_none());
    assert!(sim.rpc.is_none());
    assert!(live.sim.is_none() && live.losses.len() == live.rounds);
    assert!(live.final_params.is_some());
    assert_eq!(live.rpc.as_ref().unwrap().per_device.len(), 3);

    // The driver's Exit ends every serve loop cleanly.
    for h in handles {
        assert_eq!(h.join().unwrap().unwrap(), ServeOutcome::Clean);
    }
}

/// A bounded-staleness policy runs over the RPC transport too: the
/// version-stash semantics survive process/transport boundaries.
#[test]
fn rpc_runs_bounded_staleness_policies() {
    use asteroid::pipeline::rpc_worker::{serve, ServeOpts};
    use asteroid::schedule::policy_by_name;
    use asteroid::session::RpcBackend;
    use std::net::TcpListener;

    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..3 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve(listener, ServeOpts { die_for_real: false, verbose: false })
        }));
    }
    let session = Session::builder()
        .model("mobilenetv2")
        .cluster(ClusterSpec::env("nanos:3", 100.0).unwrap())
        .train(TrainConfig::new(8, 2))
        .planner(Planner::Baseline(Method::GpipePP))
        .schedule(policy_by_name("async:1").unwrap())
        .steps(2)
        .log_every(0)
        .build()
        .unwrap();
    let report = session.run(&mut RpcBackend::connect(addrs)).unwrap();
    assert_eq!(report.backend, "rpc");
    assert_eq!(report.max_staleness, 1);
    assert!(report.weight_stash_slots > 1);
    assert_eq!(report.rounds, 2);
    assert!(report.losses.iter().all(|l| l.is_finite() && *l > 0.0));
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// Without the pjrt feature the live backend must fail loudly, not
/// deadlock: the session surface stays one-path either way.
#[cfg(not(feature = "pjrt"))]
#[test]
fn live_engine_requires_pjrt_feature() {
    use asteroid::data::LmTask;
    use asteroid::pipeline::{train, TrainOpts};
    use asteroid::planner::{Plan, Stage};

    let plan = Plan {
        stages: vec![Stage { layers: (0, 1), devices: vec![0], alloc: vec![4], kp: 1 }],
        microbatch: 4,
        num_micro: 1,
    };
    let mut data = LmTask::new(16, 8, 4, 0);
    let err = train(
        std::path::Path::new("artifacts"),
        "lm",
        &plan,
        &TrainOpts::default(),
        &mut data,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
}

/// `SimBackend` and `PjrtBackend` must produce structurally identical
/// `RunReport`s for one small plan: same plan, same schedule, same
/// round count — the backend only changes how rounds are priced vs
/// executed.  Needs `--features pjrt` with a real binding plus
/// `make artifacts`; skips (with a note) when artifacts are absent.
#[cfg(feature = "pjrt")]
#[test]
fn sim_and_live_reports_share_structure() {
    use asteroid::session::PjrtBackend;

    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let session = Session::builder()
        .artifact_model(&artifacts, "lm")
        .cluster(ClusterSpec::env("D", 1000.0).unwrap())
        .steps(3)
        .log_every(0)
        .build()
        .unwrap();

    let sim = session.run(&mut SimBackend::default()).unwrap();
    let live = session.run(&mut PjrtBackend::new()).unwrap();

    assert_eq!(sim.plan, live.plan);
    assert_eq!(sim.schedule.policy, live.schedule.policy);
    assert_eq!(sim.rounds, live.rounds);
    assert_eq!(sim.round_secs.len(), live.round_secs.len());
    assert_eq!(sim.predicted_throughput, live.predicted_throughput);
    assert!(sim.throughput > 0.0 && live.throughput > 0.0);
    // Backend-specific halves: pricing has detail but no numerics,
    // the live engine has numerics (and the checkpoint) but no pricing.
    assert!(sim.sim.is_some() && sim.losses.is_empty() && sim.final_params.is_none());
    assert!(live.sim.is_none() && live.losses.len() == live.rounds);
    assert!(live.final_params.is_some());
}
