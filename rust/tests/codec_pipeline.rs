//! End-to-end numeric effect of the wire codecs on training.
//!
//! The live data planes transcode (encode-then-decode) every
//! inter-stage tensor, so the downstream stage computes on exactly the
//! wire's numerics.  These tests drive a two-stage [`ReferenceStage`]
//! chain — whose gradients are exact and analytic — through the same
//! transcoding step and bound the resulting gradient error per codec:
//! fp32 is bit-exact, fp16/bf16 tight, int8 documented looser (one
//! 8-bit affine grid across the whole tensor).  A second test checks
//! the property that actually matters: the loss still falls when every
//! boundary tensor rides a lossy codec.

use asteroid::codec::Codec;
use asteroid::model::{Layer, ModelDesc};
use asteroid::pipeline::step::{reference_layers, RefTask, ReferenceStage, StageCompute};
use asteroid::pipeline::OptimizerCfg;

fn tiny_model() -> ModelDesc {
    ModelDesc::new(
        "tiny",
        vec![
            Layer::new("a", 100.0, 64, 32),
            Layer::new("b", 100.0, 64, 24),
            Layer::new("head", 100.0, 64, 16),
        ],
        40,
    )
}

/// Run `rounds` single-micro rounds of a two-stage chain, transcoding
/// the boundary activation and gradient through `codec` exactly where
/// the worker data planes do.  Returns (per-round losses, the final
/// round's stage-0 input gradient).
fn chain(codec: Codec, rounds: usize, lr: f32) -> (Vec<f64>, Vec<f32>) {
    let model = tiny_model();
    let b = 4;
    let mut s0 = ReferenceStage::new(
        &reference_layers(&model, 0, 1),
        11,
        OptimizerCfg::sgd(lr),
        0,
        b,
        1,
    )
    .unwrap();
    let mut s1 = ReferenceStage::new(
        &reference_layers(&model, 1, 3),
        11,
        OptimizerCfg::sgd(lr),
        0,
        b,
        1,
    )
    .unwrap();
    let task = RefTask::new(&model, b, 11);
    let mut losses = Vec::new();
    let mut last_g0 = Vec::new();
    for round in 0..rounds {
        let (x, t) = task.microbatch(round, 0);
        let act = s0.forward(0, x).unwrap().expect("stage 0 forwards");
        let act = codec.transcode(&act);
        assert!(s1.forward(0, act).unwrap().is_none(), "head stage stashes");
        let (loss, gx) = s1.backward_head(0, t).unwrap();
        assert!(loss.is_finite(), "loss diverged under {}", codec.name());
        let gx = codec.transcode(&gx.unwrap());
        let g0 = s0.backward(0, gx).unwrap().unwrap();
        last_g0 = g0.as_f32().unwrap().to_vec();
        losses.push(loss);
        s0.end_round_local().unwrap();
        s1.end_round_local().unwrap();
    }
    (losses, last_g0)
}

/// One round from identical seeds, so the only difference between runs
/// is the codec on the two boundary crossings.  Error is measured on
/// the stage-0 input gradient — the tensor furthest downstream of both
/// transcodes — relative to the fp32 gradient's max magnitude.
#[test]
fn gradient_error_bounded_per_codec() {
    let (_, g_ref) = chain(Codec::Fp32, 1, 0.1);
    let scale = g_ref.iter().fold(0.0f32, |a, v| a.max(v.abs())).max(1e-6);
    // fp32 passthrough must be bit-exact; fp16 (10-bit mantissa) and
    // bf16 (7-bit mantissa) stay tight; int8 shares one affine grid
    // across the tensor, so its bound is documented an order looser.
    for (codec, tol) in [
        (Codec::Fp32, 0.0f32),
        (Codec::Fp16, 1e-2),
        (Codec::Bf16, 6e-2),
        (Codec::Int8, 0.25),
    ] {
        let (_, g) = chain(codec, 1, 0.1);
        assert_eq!(g.len(), g_ref.len());
        let err = g
            .iter()
            .zip(&g_ref)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
            / scale;
        assert!(
            err <= tol,
            "{}: relative gradient error {err} exceeds bound {tol}",
            codec.name()
        );
    }
}

/// The chain still learns when every boundary tensor is compressed:
/// the loss falls over 20 rounds under every codec (strictly, for the
/// tight codecs; int8's quantisation noise only has to not stall it).
#[test]
fn chain_learns_under_every_codec() {
    for codec in Codec::ALL {
        let (losses, _) = chain(codec, 20, 0.1);
        assert!(losses.iter().all(|l| l.is_finite()));
        let (first, last) = (losses[0], *losses.last().unwrap());
        match codec {
            Codec::Int8 => assert!(
                last < first,
                "int8: loss did not fall ({first} -> {last})"
            ),
            _ => assert!(
                last < first * 0.9,
                "{}: loss did not fall enough ({first} -> {last})",
                codec.name()
            ),
        }
    }
}
