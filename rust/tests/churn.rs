//! Elastic-membership churn harness (sim backend): timed traces of
//! exits, rejoins, slowdowns and link degradations executed on the
//! deterministic event clock, with the *production* drift detector in
//! the loop — these tests prove the trace grammar, the event ordering,
//! the straggler noise gate and the join-side plan re-expansion
//! end-to-end through `Session::run`.

use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::fault::{ChurnTrace, StragglerCfg};
use asteroid::session::{ChurnSpec, RecoveryKind, Session, SimBackend};

/// One session shape shared by every trace here: the paper's env D
/// chain under the default 1F1B policy (the same shape the replay
/// tests prove recovery math on).
fn session(steps: usize, spec: impl Into<ChurnSpec>) -> Session {
    Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .steps(steps)
        .churn(spec)
        .build()
        .expect("churn session builds")
}

/// The full lifecycle on one trace: a device exits (incremental heavy
/// reschedule), rejoins (join fast path re-expands to the original
/// plan), then a different device is slowed 3x and the drift detector
/// catches it after exactly `consecutive` degraded rounds.
#[test]
fn exit_join_slowdown_trace_recovers_in_order() {
    // Resolve the device ids against the planned session first.
    let probe = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .build()
        .unwrap();
    let devices = probe.plan().devices();
    assert!(devices.len() >= 2, "env D must plan a multi-device pipeline");
    let churner = *devices.last().unwrap();
    let slowed = devices[0];

    let steps = 12;
    let trace = ChurnTrace::default()
        .exit(2, churner)
        .join(5, churner)
        .slowdown(8, slowed, 3.0);
    let report = session(steps, trace).run(&mut SimBackend::default()).unwrap();

    assert_eq!(report.rounds, steps);
    assert_eq!(report.round_secs.len(), steps);
    assert_eq!(
        report.recoveries.len(),
        3,
        "exit + rejoin + straggler, in trace order"
    );

    let exit = &report.recoveries[0];
    assert_eq!(exit.round, 2);
    assert_eq!(exit.failed_device, churner);
    assert_eq!(exit.kind, RecoveryKind::HeavyIncremental);
    assert_eq!(exit.report.mechanism, "heavy-incremental");
    assert!(!exit.report.new_plan.devices().contains(&churner));

    let rejoin = &report.recoveries[1];
    assert_eq!(rejoin.round, 5);
    assert_eq!(rejoin.failed_device, churner);
    assert_eq!(rejoin.kind, RecoveryKind::Rejoin);
    assert_eq!(rejoin.report.mechanism, "rejoin");
    assert!(rejoin.report.detection_s == 0.0, "a voluntary join has no detection lag");
    assert!(rejoin.report.replan_s > 0.0, "rejoin charges measured planning time");
    // The join fast path re-expands to exactly the pre-churn plan.
    assert_eq!(
        &rejoin.report.new_plan,
        probe.plan(),
        "rejoin must round-trip to the original plan"
    );

    // Slowdown injected before round 8; with the default detector
    // (warmup 3 — satisfied by rounds 5-7 after the rejoin replan reset
    // — drift 2.0, consecutive 2) it fires on the second degraded
    // round: round 9.
    let strag = &report.recoveries[2];
    assert_eq!(strag.round, 9, "detector fires after `consecutive` degraded rounds");
    assert_eq!(strag.failed_device, slowed);
    assert_eq!(strag.kind, RecoveryKind::Straggler);
    assert_eq!(strag.report.mechanism, "straggler");
    assert!(strag.report.detection_s > 0.0, "straggler detection charges the window");

    // The round clock: degraded rounds stretch by the injected factor,
    // and the post-replan rounds recover (the plan reschedules around
    // the derated device, so they price below the degraded rounds).
    let base = report.round_secs[7];
    assert!(
        report.round_secs[8] > 2.5 * base,
        "undetected straggler must stretch the round ~3x: {} vs {base}",
        report.round_secs[8]
    );
    assert!(
        report.round_secs[10] < report.round_secs[9],
        "post-reschedule rounds must beat the degraded rounds: {} vs {}",
        report.round_secs[10],
        report.round_secs[9]
    );
    for ev in &report.recoveries {
        assert!(ev.replan_wall_s >= 0.0);
        assert!(ev.report.new_throughput > 0.0);
    }
}

/// The noise gate: a slowdown below the drift factor never fires the
/// detector — the rounds stretch, but nothing replans and no recovery
/// event is reported (no false positives).
#[test]
fn sub_threshold_slowdown_never_fires_the_detector() {
    let probe = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .build()
        .unwrap();
    let slowed = probe.plan().devices()[0];

    let steps = 10;
    // 1.5x drift against the default 2.0 threshold: visible in the
    // round clock, invisible to the detector.
    let trace = ChurnTrace::default().slowdown(3, slowed, 1.5);
    let report = session(steps, trace).run(&mut SimBackend::default()).unwrap();

    assert!(
        report.recoveries.is_empty(),
        "sub-threshold drift must not trigger a reschedule: {:?}",
        report.recoveries.iter().map(|e| e.kind).collect::<Vec<_>>()
    );
    let base = report.round_secs[2];
    for r in 3..steps {
        let ratio = report.round_secs[r] / base;
        assert!(
            (ratio - 1.5).abs() < 1e-9,
            "round {r} should run at exactly 1.5x the base latency, got {ratio}"
        );
    }
}

/// A tighter detector catches the same slowdown: threshold behaviour
/// is configuration, not hard-coding.
#[test]
fn tighter_drift_factor_catches_the_same_slowdown() {
    let probe = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .build()
        .unwrap();
    let slowed = probe.plan().devices()[0];

    let trace = ChurnTrace::default().slowdown(3, slowed, 1.5);
    let spec = ChurnSpec::from(trace).with_straggler(StragglerCfg {
        warmup_rounds: 2,
        drift_factor: 1.3,
        consecutive: 2,
    });
    let report = session(10, spec).run(&mut SimBackend::default()).unwrap();

    assert_eq!(report.recoveries.len(), 1);
    let ev = &report.recoveries[0];
    assert_eq!(ev.kind, RecoveryKind::Straggler);
    assert_eq!(ev.failed_device, slowed);
    assert_eq!(ev.round, 4, "warmup 2 (rounds 0-1), drift at 3 and 4, fires at 4");
}

/// Lightweight exits break the chained planner state (they replan
/// outside the DP); a later join must still work by rebuilding a
/// subset state — the chain-break path of the executor.
#[test]
fn join_after_lightweight_exit_rebuilds_the_chain() {
    let probe = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .build()
        .unwrap();
    let churner = *probe.plan().devices().last().unwrap();

    let trace = ChurnTrace::default().exit(1, churner).join(4, churner);
    let spec = ChurnSpec::from(trace).with_exit_recovery(RecoveryKind::Lightweight);
    let report = session(8, spec).run(&mut SimBackend::default()).unwrap();

    assert_eq!(report.recoveries.len(), 2);
    assert_eq!(report.recoveries[0].kind, RecoveryKind::Lightweight);
    assert_eq!(report.recoveries[0].report.mechanism, "lightweight");
    let rejoin = &report.recoveries[1];
    assert_eq!(rejoin.kind, RecoveryKind::Rejoin);
    assert!(
        rejoin.report.new_plan.devices().contains(&churner),
        "the rejoined device must be back in the plan"
    );
    assert_eq!(
        rejoin.report.new_plan.devices().len(),
        probe.plan().devices().len(),
        "membership must be fully restored"
    );
}

/// A link degradation replans over unchanged membership and the
/// degraded rounds price above the originals.
#[test]
fn link_degrade_replans_on_the_derated_network() {
    let probe = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .build()
        .unwrap();
    let devices = probe.plan().devices();
    let (a, b) = (devices[0], devices[1]);

    let trace = ChurnTrace::default().link_degrade(3, a, b, 5.0);
    let report = session(8, trace).run(&mut SimBackend::default()).unwrap();

    assert_eq!(report.recoveries.len(), 1);
    let ev = &report.recoveries[0];
    assert_eq!(ev.kind, RecoveryKind::Heavy);
    assert_eq!(ev.report.mechanism, "link-degrade");
    assert_eq!(ev.failed_device, a.min(b));
    assert_eq!(
        ev.report.new_plan.devices(),
        devices,
        "link events keep the membership"
    );
    assert!(
        report.round_secs[3] >= report.round_secs[2],
        "a 5 Mbps bottleneck cannot price below the 100 Mbps original: {} vs {}",
        report.round_secs[3],
        report.round_secs[2]
    );
}

/// The `--churn` grammar round-trips through `describe()` and the
/// session builder rejects traces that break membership.
#[test]
fn trace_grammar_and_session_validation() {
    let text = "exit:3@1,join:3@4,slow:0:2.5@6,link:0-1:40@7";
    let trace: ChurnTrace = text.parse().unwrap();
    assert_eq!(trace.describe(), text);
    assert_eq!(trace.len(), 4);

    // Joining a device that is still active must fail at build.
    let probe = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .build()
        .unwrap();
    let active = probe.plan().devices()[0];
    let bad = ChurnTrace::default().join(1, active);
    let err = Session::builder()
        .model("efficientnet-b1")
        .cluster(ClusterSpec::env("D", 100.0).unwrap())
        .train(TrainConfig::new(256, 16))
        .steps(8)
        .churn(bad)
        .build()
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("already active"),
        "unexpected error: {err:#}"
    );
}
