//! Fleet-scale planning invariants (the PR-6 tentpole's contracts):
//!
//! 1. **Incremental replan equivalence** — after any single-device
//!    removal, `plan_hpp_incremental` (which reuses the previous run's
//!    DP cells and memoized stage prices) must be *bit-for-bit*
//!    identical to a cold `plan_hpp_subset` rebuild over the survivors,
//!    across schedule policies, cluster shapes and removal positions.
//! 2. **Memoized pricer fidelity** — `StagePricer::stage_cost` must
//!    return exactly the `StepCost` the un-memoized
//!    `allocate_microbatch` + `exec_times_parts` + `allreduce_time_parts`
//!    path produces, and repeat queries must come from the memo.

use asteroid::codec::{Codec, CodecSpec};
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::model::zoo;
use asteroid::planner::cost::{allreduce_time_parts, exec_times_parts};
use asteroid::planner::{
    allocate_microbatch, plan_hpp_incremental, plan_hpp_incremental_join, plan_hpp_subset,
    plan_hpp_with_state, sorted_device_order, AllocOpts, PlannerConfig, StagePricer,
};
use asteroid::profiler::ProfileTable;
use asteroid::prop_assert;
use asteroid::schedule::policy_by_name;
use asteroid::util::bench::synthetic_fleet;
use asteroid::util::proptest::check;

/// Policy × cluster × removal-position sweep: the incremental fast
/// path must never change the plan, only the time it takes to find it.
#[test]
fn incremental_replan_equals_full_rebuild() {
    const POLICIES: [&str; 4] = ["1f1b-kp", "gpipe-fill-drain", "zb-h1", "async:1"];
    const ENVS: [&str; 4] = ["A", "B", "C", "D"];
    const CODECS: [Codec; 3] = [Codec::Fp32, Codec::Int8, Codec::Fp16];
    let model = zoo::mobilenet_v2();
    check(
        24,
        |rng| {
            // Half the cases exercise the paper's testbed envs, half a
            // heterogeneous synthetic fleet (8-12 devices) — big enough
            // to hit multi-device stage groups, small enough to sweep.
            let env = if rng.below(2) == 0 {
                ENVS[rng.below(ENVS.len())].to_string()
            } else {
                format!("fleet:{}", 8 + rng.below(5))
            };
            let policy = POLICIES[rng.below(POLICIES.len())];
            let removal_seed = rng.below(64);
            let codec = CODECS[rng.below(CODECS.len())];
            (env, policy, removal_seed, codec)
        },
        |case| {
            let (env, policy_name, removal_seed, codec) = (&case.0, case.1, case.2, case.3);
            let cluster = match env.strip_prefix("fleet:") {
                Some(n) => synthetic_fleet(n.parse().unwrap(), 100.0),
                None => ClusterSpec::env(env, 100.0).unwrap(),
            };
            let table = ProfileTable::new(&cluster, &model);
            let cfg = TrainConfig::new(128, 16);
            let policy = policy_by_name(policy_name).unwrap();
            let pc = PlannerConfig {
                policy,
                codec: CodecSpec::uniform(codec),
                ..PlannerConfig::default()
            };

            let (_, state) = plan_hpp_with_state(&table, &cluster, &model, &cfg, &pc)
                .map_err(|e| format!("initial plan failed: {e}"))?;
            let removed = state.order()[removal_seed % state.order().len()];
            let keep: Vec<usize> =
                state.order().iter().copied().filter(|&d| d != removed).collect();

            let inc = plan_hpp_incremental(&state, &table, &cluster, &model, &cfg, &pc, removed);
            let full = plan_hpp_subset(&table, &cluster, &model, &cfg, &pc, &keep);
            match (inc, full) {
                (Ok((i, _)), Ok((f, _))) => {
                    prop_assert!(
                        i.plan == f.plan,
                        "plans diverge after removing {removed}:\n inc {:?}\n full {:?}",
                        i.plan,
                        f.plan
                    );
                    prop_assert!(
                        i.predicted_latency.to_bits() == f.predicted_latency.to_bits(),
                        "latency diverges: inc {} vs full {}",
                        i.predicted_latency,
                        f.predicted_latency
                    );
                    Ok(())
                }
                (Err(_), Err(_)) => Ok(()), // both infeasible: consistent
                (inc, full) => Err(format!(
                    "feasibility diverges after removing {removed}: inc ok={}, full ok={}",
                    inc.is_ok(),
                    full.is_ok()
                )),
            }
        },
    );
}

/// Join-side mirror of the removal sweep: re-admitting a device
/// through `plan_hpp_incremental_join` (which reuses every DP chain
/// the insertion provably cannot disturb) must be *bit-for-bit*
/// identical to a cold full rebuild over the union — across schedule
/// policies, cluster shapes, wire codecs and insertion positions.
#[test]
fn join_incremental_equals_full_rebuild() {
    const POLICIES: [&str; 4] = ["1f1b-kp", "gpipe-fill-drain", "zb-h1", "async:1"];
    const ENVS: [&str; 4] = ["A", "B", "C", "D"];
    const CODECS: [Codec; 3] = [Codec::Fp32, Codec::Int8, Codec::Fp16];
    let model = zoo::mobilenet_v2();
    check(
        24,
        |rng| {
            let env = if rng.below(2) == 0 {
                ENVS[rng.below(ENVS.len())].to_string()
            } else {
                format!("fleet:{}", 8 + rng.below(5))
            };
            let policy = POLICIES[rng.below(POLICIES.len())];
            let held_seed = rng.below(64);
            let codec = CODECS[rng.below(CODECS.len())];
            (env, policy, held_seed, codec)
        },
        |case| {
            let (env, policy_name, held_seed, codec) = (&case.0, case.1, case.2, case.3);
            let cluster = match env.strip_prefix("fleet:") {
                Some(n) => synthetic_fleet(n.parse().unwrap(), 100.0),
                None => ClusterSpec::env(env, 100.0).unwrap(),
            };
            if cluster.n() < 2 {
                return Ok(()); // joining needs a proper subset to start from
            }
            let table = ProfileTable::new(&cluster, &model);
            let cfg = TrainConfig::new(128, 16);
            let policy = policy_by_name(policy_name).unwrap();
            let pc = PlannerConfig {
                policy,
                codec: CodecSpec::uniform(codec),
                ..PlannerConfig::default()
            };

            // Hold one device out, plan the rest, then join it back.
            let all: Vec<usize> = (0..cluster.n()).collect();
            let added = all[held_seed % all.len()];
            let base: Vec<usize> =
                all.iter().copied().filter(|&d| d != added).collect();
            let prev = match plan_hpp_subset(&table, &cluster, &model, &cfg, &pc, &base) {
                Ok((_, st)) => st,
                Err(_) => return Ok(()), // base subset infeasible: nothing to join onto
            };

            let inc =
                plan_hpp_incremental_join(&prev, &table, &cluster, &model, &cfg, &pc, added);
            let full = plan_hpp_subset(&table, &cluster, &model, &cfg, &pc, &all);
            match (inc, full) {
                (Ok((i, _)), Ok((f, _))) => {
                    prop_assert!(
                        i.plan == f.plan,
                        "plans diverge after joining {added}:\n inc {:?}\n full {:?}",
                        i.plan,
                        f.plan
                    );
                    prop_assert!(
                        i.predicted_latency.to_bits() == f.predicted_latency.to_bits(),
                        "latency diverges: inc {} vs full {}",
                        i.predicted_latency,
                        f.predicted_latency
                    );
                    Ok(())
                }
                (Err(_), Err(_)) => Ok(()), // both infeasible: consistent
                (inc, full) => Err(format!(
                    "feasibility diverges after joining {added}: inc ok={}, full ok={}",
                    inc.is_ok(),
                    full.is_ok()
                )),
            }
        },
    );
}

/// Remove-then-rejoin round trip through both incremental paths: the
/// re-expanded plan must be bit-for-bit the original full plan (the
/// chained planner state loses nothing across the dip), across the
/// same policy × cluster × codec sweep.
#[test]
fn remove_then_rejoin_round_trips() {
    const POLICIES: [&str; 4] = ["1f1b-kp", "gpipe-fill-drain", "zb-h1", "async:1"];
    const ENVS: [&str; 4] = ["A", "B", "C", "D"];
    const CODECS: [Codec; 3] = [Codec::Fp32, Codec::Int8, Codec::Fp16];
    let model = zoo::mobilenet_v2();
    check(
        16,
        |rng| {
            let env = if rng.below(2) == 0 {
                ENVS[rng.below(ENVS.len())].to_string()
            } else {
                format!("fleet:{}", 8 + rng.below(5))
            };
            let policy = POLICIES[rng.below(POLICIES.len())];
            let dev_seed = rng.below(64);
            let codec = CODECS[rng.below(CODECS.len())];
            (env, policy, dev_seed, codec)
        },
        |case| {
            let (env, policy_name, dev_seed, codec) = (&case.0, case.1, case.2, case.3);
            let cluster = match env.strip_prefix("fleet:") {
                Some(n) => synthetic_fleet(n.parse().unwrap(), 100.0),
                None => ClusterSpec::env(env, 100.0).unwrap(),
            };
            let table = ProfileTable::new(&cluster, &model);
            let cfg = TrainConfig::new(128, 16);
            let policy = policy_by_name(policy_name).unwrap();
            let pc = PlannerConfig {
                policy,
                codec: CodecSpec::uniform(codec),
                ..PlannerConfig::default()
            };

            let (orig, state) = match plan_hpp_with_state(&table, &cluster, &model, &cfg, &pc) {
                Ok(r) => r,
                Err(_) => return Ok(()), // whole cluster infeasible under this policy
            };
            if state.order().len() < 2 {
                return Ok(());
            }
            let dev = state.order()[dev_seed % state.order().len()];

            // Dip: remove `dev` through the shrink fast path...
            let shrunk =
                match plan_hpp_incremental(&state, &table, &cluster, &model, &cfg, &pc, dev) {
                    Ok((_, st)) => st,
                    Err(_) => return Ok(()), // survivors infeasible: no dip to recover from
                };
            // ...and rejoin it through the join fast path.
            let (back, expanded) =
                plan_hpp_incremental_join(&shrunk, &table, &cluster, &model, &cfg, &pc, dev)
                    .map_err(|e| format!("rejoin of {dev} failed: {e}"))?;

            prop_assert!(
                back.plan == orig.plan,
                "round trip changed the plan for device {dev}:\n orig {:?}\n back {:?}",
                orig.plan,
                back.plan
            );
            prop_assert!(
                back.predicted_latency.to_bits() == orig.predicted_latency.to_bits(),
                "round trip changed the latency: {} vs {}",
                orig.predicted_latency,
                back.predicted_latency
            );
            prop_assert!(
                expanded.order().len() == state.order().len(),
                "re-expanded state covers {} devices, expected {}",
                expanded.order().len(),
                state.order().len()
            );
            Ok(())
        },
    );
}

/// `StagePricer::stage_cost` vs the raw pricing path on every
/// (layer-range, group-size) candidate of the env-C chain: identical
/// bits, and the second sweep served entirely from the memo.
#[test]
fn memoized_pricer_matches_unmemoized_path_env_c() {
    let cluster = ClusterSpec::env("C", 100.0).unwrap();
    let model = zoo::mobilenet_v2();
    let table = ProfileTable::new(&cluster, &model);
    let cfg = TrainConfig::new(128, 16);
    let pc = PlannerConfig::default();
    let m = cfg.num_microbatches();
    let b = cfg.microbatch;
    let ids: Vec<usize> = (0..cluster.n()).collect();
    let order = sorted_device_order(&cluster, &ids);
    let nl = model.num_layers();

    let mut pricer = StagePricer::new();
    let mut candidates = 0usize;
    for g in 1..=order.len() {
        let devices = &order[..g];
        for i in (0..nl).step_by(7) {
            for j in ((i + 1)..=nl).step_by(5) {
                let kp = (m / 2).max(1);
                let memoized = pricer
                    .stage_cost(&table, &cluster, &model, &cfg, &pc, i, j, devices, kp);

                // The raw path, exactly as the pre-memo planner priced it.
                let eff_kp = pc.policy.effective_kp(kp, m);
                let opts = AllocOpts {
                    stash_copies: pc.policy.weight_stash_copies(kp, m),
                    ..pc.alloc
                };
                let raw = allocate_microbatch(
                    &table, &cluster, &model, &cfg, i, j, devices, b, eff_kp, opts,
                )
                .ok()
                .map(|alloc| {
                    let (ef, eb) = exec_times_parts(&table, i, j, devices, &alloc);
                    let ta_raw = if g <= 1 {
                        0.0
                    } else {
                        allreduce_time_parts(
                            model.weight_bytes_range(i, j),
                            g,
                            cluster.min_bandwidth(devices),
                        )
                    };
                    (ef, eb, if pc.comm_aware { ta_raw } else { 0.0 })
                });

                match (memoized, raw) {
                    (Some(c), Some((ef, eb, ta))) => {
                        assert_eq!(c.ef.to_bits(), ef.to_bits(), "ef differs at ({i},{j},{g})");
                        assert_eq!(c.eb.to_bits(), eb.to_bits(), "eb differs at ({i},{j},{g})");
                        assert_eq!(c.ta.to_bits(), ta.to_bits(), "ta differs at ({i},{j},{g})");
                        assert!(c.exec);
                    }
                    (None, None) => {} // OOM is memoized too
                    (memoized, raw) => panic!(
                        "feasibility differs at ({i},{j},{g}): memo {} raw {}",
                        memoized.is_some(),
                        raw.is_some()
                    ),
                }
                candidates += 1;
            }
        }
    }
    assert!(candidates > 50, "sweep too small: {candidates}");
    assert_eq!(pricer.misses(), candidates as u64);

    // Second identical sweep: pure memo hits, identical answers.
    let misses_before = pricer.misses();
    for g in 1..=order.len() {
        let devices = &order[..g];
        for i in (0..nl).step_by(7) {
            for j in ((i + 1)..=nl).step_by(5) {
                let kp = (m / 2).max(1);
                pricer.stage_cost(&table, &cluster, &model, &cfg, &pc, i, j, devices, kp);
            }
        }
    }
    assert_eq!(pricer.misses(), misses_before, "second sweep must not recompute");
    assert_eq!(pricer.hits(), candidates as u64);
}

/// The codec fingerprint is part of the stage-price memo key: pricing
/// the same candidate under fp32 and int8 must occupy two memo slots
/// (never alias), agree bit-for-bit on the compute terms, and charge
/// strictly less AllReduce time for the compressed wire.
#[test]
fn pricer_memo_keys_codecs_separately() {
    let cluster = ClusterSpec::env("C", 50.0).unwrap();
    let model = zoo::mobilenet_v2();
    let table = ProfileTable::new(&cluster, &model);
    let cfg = TrainConfig::new(128, 16);
    let ids: Vec<usize> = (0..cluster.n()).collect();
    let order = sorted_device_order(&cluster, &ids);
    assert!(order.len() > 1, "need a replicated group for a T_a term");
    let kp = (cfg.num_microbatches() / 2).max(1);
    // A modest layer slice across the whole group: always feasible,
    // carries weights (so the AllReduce flats are non-empty).
    let (i, j) = (0, 7.min(model.num_layers()));
    let pc_fp = PlannerConfig::default();
    let pc_q8 = PlannerConfig {
        codec: CodecSpec::uniform(Codec::Int8),
        ..PlannerConfig::default()
    };

    let mut pricer = StagePricer::new();
    let a = pricer
        .stage_cost(&table, &cluster, &model, &cfg, &pc_fp, i, j, &order, kp)
        .expect("fp32 candidate feasible");
    let b = pricer
        .stage_cost(&table, &cluster, &model, &cfg, &pc_q8, i, j, &order, kp)
        .expect("int8 candidate feasible");
    assert_eq!(pricer.misses(), 2, "distinct codecs must fill distinct memo slots");
    assert_eq!(a.ef.to_bits(), b.ef.to_bits(), "codec must not change compute");
    assert_eq!(a.eb.to_bits(), b.eb.to_bits(), "codec must not change compute");
    assert!(
        b.ta < a.ta,
        "int8 AllReduce must price below fp32: {} vs {}",
        b.ta,
        a.ta
    );

    // Re-queries are pure hits and bit-identical per codec.
    let a2 = pricer
        .stage_cost(&table, &cluster, &model, &cfg, &pc_fp, i, j, &order, kp)
        .unwrap();
    let b2 = pricer
        .stage_cost(&table, &cluster, &model, &cfg, &pc_q8, i, j, &order, kp)
        .unwrap();
    assert_eq!(pricer.misses(), 2);
    assert_eq!(pricer.hits(), 2);
    assert_eq!(a2.ta.to_bits(), a.ta.to_bits());
    assert_eq!(b2.ta.to_bits(), b.ta.to_bits());
}
