//! Property-based tests over the planner's invariants:
//! random heterogeneous clusters, models and training configs must
//! always yield plans that are structurally valid, memory-safe,
//! allocation-complete and consistent between the analytic cost model
//! and the event-accurate simulator.

use asteroid::config::{ClusterSpec, DeviceKind, TrainConfig};
use asteroid::model::zoo;
use asteroid::planner::alloc::{allocate_microbatch, AllocOpts};
use asteroid::planner::cost::{plan_peak_memory, plan_steps, round_latency};
use asteroid::planner::dp::{plan_hpp, PlannerConfig};
use asteroid::profiler::ProfileTable;
use asteroid::sim::simulate_round;
use asteroid::util::proptest::check;
use asteroid::util::rng::Rng;

/// Random heterogeneous cluster of 2..=7 devices.
fn random_cluster(rng: &mut Rng) -> ClusterSpec {
    let kinds = [DeviceKind::JetsonNano, DeviceKind::JetsonTX2, DeviceKind::JetsonNX];
    let n = rng.range(2, 8);
    let devs: Vec<DeviceKind> = (0..n).map(|_| *rng.choose(&kinds)).collect();
    let mbps = *rng.choose(&[50.0, 100.0, 300.0, 1000.0]);
    ClusterSpec::uniform(&devs, mbps)
}

fn random_model(rng: &mut Rng) -> asteroid::model::ModelDesc {
    match rng.below(3) {
        0 => zoo::mobilenet_v2(),
        1 => zoo::efficientnet_b1(),
        _ => zoo::bert_small(),
    }
}

fn random_cfg(rng: &mut Rng) -> TrainConfig {
    let micro = *rng.choose(&[4usize, 8, 16, 32]);
    let m = rng.range(2, 33);
    TrainConfig::new(micro * m, micro)
}

#[test]
fn prop_plans_always_validate_and_fit_memory() {
    check(
        40,
        |rng| {
            let c = random_cluster(rng);
            let m = random_model(rng);
            let cfg = random_cfg(rng);
            (c, m, cfg)
        },
        |(cluster, model, cfg)| {
            let table = ProfileTable::new(cluster, model);
            match plan_hpp(&table, cluster, model, cfg, &PlannerConfig::default()) {
                Err(_) => Ok(()), // infeasible is a legal outcome
                Ok(out) => {
                    out.plan
                        .validate(model, cluster)
                        .map_err(|e| format!("invalid plan: {e:#}"))?;
                    for (d, used) in
                        plan_peak_memory(model, cfg, &out.plan, asteroid::schedule::DEFAULT_POLICY)
                    {
                        if used > cluster.devices[d].mem_bytes {
                            return Err(format!(
                                "memory violated on {d}: {used} > {}",
                                cluster.devices[d].mem_bytes
                            ));
                        }
                    }
                    if !(out.predicted_throughput.is_finite() && out.predicted_throughput > 0.0) {
                        return Err("non-positive predicted throughput".into());
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_alloc_conserves_and_respects_limits() {
    check(
        60,
        |rng| {
            let cluster = random_cluster(rng);
            let model = random_model(rng);
            let cfg = random_cfg(rng);
            let n = cluster.n();
            let g = rng.range(1, n + 1);
            let mut devs: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut devs);
            devs.truncate(g);
            let nl = model.num_layers();
            let i = rng.below(nl - 1);
            let j = rng.range(i + 1, nl + 1);
            let kp = rng.range(1, 6);
            (cluster, model, cfg, devs, i, j, kp)
        },
        |(cluster, model, cfg, devs, i, j, kp)| {
            let table = ProfileTable::new(cluster, model);
            match allocate_microbatch(
                &table, cluster, model, cfg, *i, *j, devs, cfg.microbatch, *kp,
                AllocOpts::default(),
            ) {
                Err(_) => Ok(()), // OOM is legal
                Ok(alloc) => {
                    if alloc.len() != devs.len() {
                        return Err("alloc arity".into());
                    }
                    let total: usize = alloc.iter().sum();
                    if total != cfg.microbatch {
                        return Err(format!("allocated {total} != {}", cfg.microbatch));
                    }
                    // Memory limits hold per device.
                    for (&d, &y) in devs.iter().zip(&alloc) {
                        let cap = asteroid::planner::memory::max_batch_under_budget(
                            model, cfg, *i, *j, *kp, 0, &cluster.devices[d],
                        );
                        if y > cap {
                            return Err(format!("device {d}: alloc {y} > cap {cap}"));
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_sim_and_cost_model_agree() {
    // The dominant-step approximation and the event-accurate simulator
    // must stay within a modest constant factor on planner-chosen
    // plans — this guards both against drifting.
    check(
        15,
        |rng| {
            let c = random_cluster(rng);
            let m = random_model(rng);
            let cfg = random_cfg(rng);
            (c, m, cfg)
        },
        |(cluster, model, cfg)| {
            let table = ProfileTable::new(cluster, model);
            let Ok(out) = plan_hpp(&table, cluster, model, cfg, &PlannerConfig::default())
            else {
                return Ok(());
            };
            let steps = plan_steps(&table, cluster, model, &out.plan);
            let predicted = round_latency(&steps, out.plan.num_micro);
            let sim = simulate_round(&table, cluster, model, &out.plan);
            let ratio = sim.round_latency / predicted;
            if !(0.4..=2.5).contains(&ratio) {
                return Err(format!(
                    "cost model drift: sim {} vs predicted {predicted} (ratio {ratio})",
                    sim.round_latency
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_conservation_and_memory_bounds() {
    // Simulator invariants: K_p bounds in-flight micro-batches, busy
    // time is positive on every participating device, and the network
    // byte count matches the plan's analytic volume.
    check(
        20,
        |rng| {
            let c = random_cluster(rng);
            let m = random_model(rng);
            let cfg = random_cfg(rng);
            (c, m, cfg)
        },
        |(cluster, model, cfg)| {
            let table = ProfileTable::new(cluster, model);
            let Ok(out) = plan_hpp(&table, cluster, model, cfg, &PlannerConfig::default())
            else {
                return Ok(());
            };
            let sim = simulate_round(&table, cluster, model, &out.plan);
            for stage in &out.plan.stages {
                for (&d, &share) in stage.devices.iter().zip(&stage.alloc) {
                    if sim.peak_inflight[d] > stage.kp {
                        return Err(format!(
                            "device {d}: inflight {} > K_p {}",
                            sim.peak_inflight[d], stage.kp
                        ));
                    }
                    // Algorithm 1 may give a weak device zero samples —
                    // it then legitimately idles; every device with a
                    // share must compute.
                    if share > 0 && sim.busy[d] <= 0.0 {
                        return Err(format!("device {d} never computed"));
                    }
                }
            }
            let expected = asteroid::comm::hpp_volume(model, &out.plan);
            if sim.bytes_on_network != expected {
                return Err(format!(
                    "network bytes {} != Eq.2 volume {expected}",
                    sim.bytes_on_network
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_replan_preserves_coverage_after_any_failure() {
    check(
        25,
        |rng| {
            let c = random_cluster(rng);
            let m = random_model(rng);
            let cfg = random_cfg(rng);
            let pick = rng.next_u64();
            (c, m, cfg, pick)
        },
        |(cluster, model, cfg, pick)| {
            let table = ProfileTable::new(cluster, model);
            let Ok(out) = plan_hpp(&table, cluster, model, cfg, &PlannerConfig::default())
            else {
                return Ok(());
            };
            let devs = out.plan.devices();
            if devs.len() < 2 {
                return Ok(());
            }
            let failed = devs[(*pick as usize) % devs.len()];
            match asteroid::fault::lightweight_replan(
                &table, cluster, model, cfg, &out.plan, failed,
            ) {
                Err(_) => Ok(()), // survivors may legitimately OOM
                Ok(r) => {
                    r.plan
                        .validate(model, cluster)
                        .map_err(|e| format!("replan invalid: {e:#}"))?;
                    if r.plan.devices().contains(&failed) {
                        return Err("failed device still in plan".into());
                    }
                    Ok(())
                }
            }
        },
    );
}
