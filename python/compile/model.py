"""Layer-2: JAX stage models for Asteroid's pipeline-parallel training.

The paper trains vision CNNs (EfficientNet-B1 / MobileNetV2 / ResNet50)
and a language model (Bert-small) split into *pipeline stages*.  This
module defines the two workload families we execute for real through the
Rust coordinator:

  * ``lm``  — a decoder transformer LM (the Bert-small analogue), built
    from three stage kinds: ``embed`` -> N x ``block`` -> ``head``.  All
    blocks share shapes, so ONE ``block_fwd``/``block_bwd`` HLO serves
    every block; a pipeline stage of k consecutive blocks simply runs the
    same executable k times with its own weights.
  * ``cnn`` — a CIFAR-style CNN (the MobileNetV2 analogue) with stage
    kinds ``stem`` -> ``block1`` -> ``block2`` -> ``block3`` -> ``head``.

Every stage kind exposes:
  ``<kind>_fwd(params, x)``                  -> y
  ``<kind>_bwd(params, x, gy)``              -> (*gparams, gx)   (rematerialising)
  head: ``head_fwdbwd(params, x, targets)``  -> (loss, *gparams, gx)
        ``head_loss(params, x, targets)``    -> loss             (eval)

The backward passes re-run the forward under ``jax.vjp`` inside one HLO,
so the only tensor stashed between a micro-batch's FP and BP is the
stage *input* — exactly the activation term the paper's Eq. (3) memory
model counts per in-flight micro-batch.

Compute hot-spots call the Layer-1 Pallas kernels (``backend="pallas"``,
the default) or the pure-jnp oracles (``backend="ref"``) for debugging.
Python runs only at build time: ``aot.py`` lowers each function to HLO
text, and the Rust runtime executes the artifacts.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref as kref


# --------------------------------------------------------------------------
# Kernel backend selection
# --------------------------------------------------------------------------

class _PallasOps:
    matmul = staticmethod(kernels.matmul)
    attention = staticmethod(kernels.attention)
    layernorm = staticmethod(kernels.layernorm)


class _RefOps:
    matmul = staticmethod(kref.ref_matmul)
    attention = staticmethod(kref.ref_attention)
    layernorm = staticmethod(kref.ref_layernorm)


def get_ops(backend: str):
    if backend == "pallas":
        return _PallasOps
    if backend == "ref":
        return _RefOps
    raise ValueError(f"unknown backend {backend!r}")


# --------------------------------------------------------------------------
# Parameter specifications (shared with the Rust side via the manifest)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter tensor of a stage kind; `init` in {normal, zeros, ones}."""
    name: str
    shape: tuple
    init: str = "normal"
    scale: float = 0.02

    def to_json(self) -> dict:
        return {"name": self.name, "shape": list(self.shape),
                "init": self.init, "scale": self.scale}


def init_params(specs: Sequence[ParamSpec], key: jax.Array) -> tuple:
    """Initialise a stage-kind parameter tuple (test/reference use; the
    Rust coordinator does its own init from the manifest)."""
    out = []
    for spec in specs:
        key, sub = jax.random.split(key)
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, jnp.float32))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, jnp.float32))
        else:
            out.append(spec.scale * jax.random.normal(sub, spec.shape, jnp.float32))
    return tuple(out)


# --------------------------------------------------------------------------
# Transformer LM
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Decoder transformer LM dimensions.  Defaults give a ~0.9M-param
    model that trains in minutes on the single-core CPU substrate; the
    ``lm-base`` preset in aot.py scales to multi-million params."""
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    seq: int = 64
    n_blocks: int = 4
    microbatch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def lm_embed_specs(c: LMConfig) -> list[ParamSpec]:
    return [
        ParamSpec("tok_emb", (c.vocab, c.d_model)),
        ParamSpec("pos_emb", (c.seq, c.d_model), scale=0.01),
    ]


def lm_block_specs(c: LMConfig) -> list[ParamSpec]:
    d, f = c.d_model, c.d_ff
    return [
        ParamSpec("ln1_scale", (d,), init="ones"),
        ParamSpec("ln1_bias", (d,), init="zeros"),
        ParamSpec("wq", (d, d)),
        ParamSpec("wk", (d, d)),
        ParamSpec("wv", (d, d)),
        ParamSpec("wo", (d, d)),
        ParamSpec("ln2_scale", (d,), init="ones"),
        ParamSpec("ln2_bias", (d,), init="zeros"),
        ParamSpec("w1", (d, f)),
        ParamSpec("b1", (f,), init="zeros"),
        ParamSpec("w2", (f, d)),
        ParamSpec("b2", (d,), init="zeros"),
    ]


def lm_head_specs(c: LMConfig) -> list[ParamSpec]:
    return [
        ParamSpec("lnf_scale", (c.d_model,), init="ones"),
        ParamSpec("lnf_bias", (c.d_model,), init="zeros"),
        ParamSpec("w_out", (c.d_model, c.vocab)),
    ]


def lm_embed_fwd(c: LMConfig, params: tuple, tokens: jax.Array) -> jax.Array:
    """(B, S) int32 tokens -> (B, S, D) activations."""
    tok_emb, pos_emb = params
    return jnp.take(tok_emb, tokens, axis=0) + pos_emb[None, :, :]


def lm_embed_bwd(c: LMConfig, params: tuple, tokens: jax.Array,
                 g: jax.Array) -> tuple:
    """Gradients of the embedding tables (no input gradient: first layer)."""
    _, vjp = jax.vjp(lambda p: lm_embed_fwd(c, p, tokens), params)
    (gp,) = vjp(g)
    return tuple(gp)


def lm_block_fwd(c: LMConfig, params: tuple, x: jax.Array,
                 backend: str = "pallas") -> jax.Array:
    """Pre-norm transformer block: attention + FFN with residuals."""
    ops = get_ops(backend)
    (ln1_s, ln1_b, wq, wk, wv, wo, ln2_s, ln2_b, w1, b1, w2, b2) = params
    b, s, d = x.shape
    h, hd = c.n_heads, c.head_dim

    x2 = x.reshape(b * s, d)
    hn = ops.layernorm(x2, ln1_s, ln1_b)
    q = ops.matmul(hn, wq).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = ops.matmul(hn, wk).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = ops.matmul(hn, wv).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = ops.attention(q, k, v, True)
    att = att.transpose(0, 2, 1, 3).reshape(b * s, d)
    x2 = x2 + ops.matmul(att, wo)

    hn2 = ops.layernorm(x2, ln2_s, ln2_b)
    ff = jax.nn.gelu(ops.matmul(hn2, w1) + b1)
    x2 = x2 + ops.matmul(ff, w2) + b2
    return x2.reshape(b, s, d)


def lm_block_bwd(c: LMConfig, params: tuple, x: jax.Array, g: jax.Array,
                 backend: str = "pallas") -> tuple:
    """Rematerialising backward: (*gparams, gx)."""
    _, vjp = jax.vjp(lambda p, x_: lm_block_fwd(c, p, x_, backend), params, x)
    gp, gx = vjp(g)
    return (*gp, gx)


def _softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean cross-entropy over all positions (stable logsumexp)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def lm_head_loss(c: LMConfig, params: tuple, x: jax.Array,
                 targets: jax.Array, backend: str = "pallas") -> jax.Array:
    """Final layernorm + output projection + mean token cross-entropy."""
    ops = get_ops(backend)
    lnf_s, lnf_b, w_out = params
    b, s, d = x.shape
    hn = ops.layernorm(x.reshape(b * s, d), lnf_s, lnf_b)
    logits = ops.matmul(hn, w_out).reshape(b, s, c.vocab)
    return _softmax_xent(logits, targets)


def lm_head_fwdbwd(c: LMConfig, params: tuple, x: jax.Array,
                   targets: jax.Array, backend: str = "pallas") -> tuple:
    """Loss plus gradients w.r.t. head params and stage input."""
    loss, (gp, gx) = jax.value_and_grad(
        lambda p, x_: lm_head_loss(c, p, x_, targets, backend),
        argnums=(0, 1))(params, x)
    return (loss, *gp, gx)


def lm_full_loss(c: LMConfig, all_params: tuple, tokens: jax.Array,
                 targets: jax.Array, backend: str = "pallas") -> jax.Array:
    """Composed full-model loss: embed -> blocks -> head.  Used by the
    python tests to validate the stage decomposition against end-to-end
    autodiff; never lowered for the Rust runtime."""
    embed_p, block_ps, head_p = all_params
    h = lm_embed_fwd(c, embed_p, tokens)
    for bp in block_ps:
        h = lm_block_fwd(c, bp, h, backend)
    return lm_head_loss(c, head_p, h, targets, backend)


# --------------------------------------------------------------------------
# CIFAR-style CNN
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Small CIFAR CNN (MobileNetV2 analogue for the real-exec path).

    32x32x3 -> stem -> 3 down-sampling conv blocks -> GAP head."""
    hw: int = 32
    in_ch: int = 3
    channels: tuple = (16, 32, 64)
    classes: int = 10
    microbatch: int = 16


def _conv_specs(name: str, cin: int, cout: int) -> list[ParamSpec]:
    fan_in = 9 * cin
    return [
        ParamSpec(f"{name}_w", (3, 3, cin, cout), scale=(2.0 / fan_in) ** 0.5),
        ParamSpec(f"{name}_b", (cout,), init="zeros"),
    ]


def cnn_stem_specs(c: CNNConfig) -> list[ParamSpec]:
    return _conv_specs("stem", c.in_ch, c.channels[0])


def cnn_block_specs(c: CNNConfig, i: int) -> list[ParamSpec]:
    cin = c.channels[i - 1] if i > 0 else c.channels[0]
    cout = c.channels[i]
    return _conv_specs(f"b{i}c1", cin, cout) + _conv_specs(f"b{i}c2", cout, cout)


def cnn_head_specs(c: CNNConfig) -> list[ParamSpec]:
    return [
        ParamSpec("fc_w", (c.channels[-1], c.classes),
                  scale=(1.0 / c.channels[-1]) ** 0.5),
        ParamSpec("fc_b", (c.classes,), init="zeros"),
    ]


def _conv(x: jax.Array, w: jax.Array, b: jax.Array,
          stride: int = 1) -> jax.Array:
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def cnn_stem_fwd(c: CNNConfig, params: tuple, x: jax.Array) -> jax.Array:
    w, b = params
    return jax.nn.relu(_conv(x, w, b))


def cnn_block_fwd(c: CNNConfig, i: int, params: tuple,
                  x: jax.Array) -> jax.Array:
    """conv-relu, conv-relu, then 2x2 stride-2 downsample (maxpool)."""
    w1, b1, w2, b2 = params
    h = jax.nn.relu(_conv(x, w1, b1))
    h = jax.nn.relu(_conv(h, w2, b2))
    return jax.lax.reduce_window(
        h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_head_loss(c: CNNConfig, params: tuple, x: jax.Array,
                  labels: jax.Array) -> jax.Array:
    fc_w, fc_b = params
    pooled = jnp.mean(x, axis=(1, 2))  # global average pool
    logits = pooled @ fc_w + fc_b
    return _softmax_xent(logits, labels)


def _stage_bwd(fwd: Callable, params: tuple, x: jax.Array,
               g: jax.Array) -> tuple:
    _, vjp = jax.vjp(fwd, params, x)
    gp, gx = vjp(g)
    return (*gp, gx)


def cnn_stem_bwd(c, params, x, g):
    return _stage_bwd(lambda p, x_: cnn_stem_fwd(c, p, x_), params, x, g)


def cnn_block_bwd(c, i, params, x, g):
    return _stage_bwd(lambda p, x_: cnn_block_fwd(c, i, p, x_), params, x, g)


def cnn_head_fwdbwd(c, params, x, labels):
    loss, (gp, gx) = jax.value_and_grad(
        lambda p, x_: cnn_head_loss(c, p, x_, labels),
        argnums=(0, 1))(params, x)
    return (loss, *gp, gx)


def cnn_full_loss(c: CNNConfig, all_params: tuple, x: jax.Array,
                  labels: jax.Array) -> jax.Array:
    stem_p, block_ps, head_p = all_params
    h = cnn_stem_fwd(c, stem_p, x)
    for i, bp in enumerate(block_ps):
        h = cnn_block_fwd(c, i, bp, h)
    return cnn_head_loss(c, head_p, h, labels)


# --------------------------------------------------------------------------
# Artifact registry (consumed by aot.py)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Artifact:
    """One AOT-lowered computation: `fn(*args)` with example arg shapes."""
    name: str
    fn: Callable
    args: list          # ShapeDtypeStructs, in HLO parameter order
    arg_names: list     # human-readable names, same order
    out_names: list     # names of tuple outputs


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _param_sds(specs: Sequence[ParamSpec]) -> list:
    return [_sds(s.shape) for s in specs]


def lm_artifacts(c: LMConfig, backend: str = "pallas") -> list[Artifact]:
    """Every HLO the Rust runtime needs to train the LM."""
    B, S, D, V = c.microbatch, c.seq, c.d_model, c.vocab
    e_specs, b_specs, h_specs = lm_embed_specs(c), lm_block_specs(c), lm_head_specs(c)
    tok = _sds((B, S), jnp.int32)
    act = _sds((B, S, D))

    def names(specs, pre=""):
        return [pre + s.name for s in specs]

    return [
        Artifact("embed_fwd",
                 lambda p, t: (lm_embed_fwd(c, p, t),),
                 [tuple(_param_sds(e_specs)), tok],
                 names(e_specs) + ["tokens"], ["act"]),
        Artifact("embed_bwd",
                 lambda p, t, g: lm_embed_bwd(c, p, t, g),
                 [tuple(_param_sds(e_specs)), tok, act],
                 names(e_specs) + ["tokens", "grad_in"],
                 names(e_specs, "g_")),
        Artifact("block_fwd",
                 lambda p, x: (lm_block_fwd(c, p, x, backend),),
                 [tuple(_param_sds(b_specs)), act],
                 names(b_specs) + ["x"], ["act"]),
        Artifact("block_bwd",
                 lambda p, x, g: lm_block_bwd(c, p, x, g, backend),
                 [tuple(_param_sds(b_specs)), act, act],
                 names(b_specs) + ["x", "grad_in"],
                 names(b_specs, "g_") + ["g_x"]),
        Artifact("head_fwdbwd",
                 lambda p, x, t: lm_head_fwdbwd(c, p, x, t, backend),
                 [tuple(_param_sds(h_specs)), act, tok],
                 names(h_specs) + ["x", "targets"],
                 ["loss"] + names(h_specs, "g_") + ["g_x"]),
        Artifact("head_loss",
                 lambda p, x, t: (lm_head_loss(c, p, x, t, backend),),
                 [tuple(_param_sds(h_specs)), act, tok],
                 names(h_specs) + ["x", "targets"], ["loss"]),
    ]


def cnn_artifacts(c: CNNConfig) -> list[Artifact]:
    B, HW = c.microbatch, c.hw
    ch = c.channels
    stem_specs = cnn_stem_specs(c)
    head_specs = cnn_head_specs(c)
    img = _sds((B, HW, HW, c.in_ch))
    lbl = _sds((B,), jnp.int32)

    # activation shapes *entering* each block / the head.  Block i maps
    # (hw, cin_i) -> (hw/2, ch[i]) where cin_0 = ch[0] (stem output) and
    # cin_i = ch[i-1] otherwise.
    act_in = []
    hw = HW
    for i in range(len(ch)):
        cin = ch[0] if i == 0 else ch[i - 1]
        act_in.append((B, hw, hw, cin))
        hw //= 2
    head_in = (B, hw, hw, ch[-1])

    def names(specs, pre=""):
        return [pre + s.name for s in specs]

    arts = [
        Artifact("stem_fwd",
                 lambda p, x: (cnn_stem_fwd(c, p, x),),
                 [tuple(_param_sds(stem_specs)), img],
                 names(stem_specs) + ["x"], ["act"]),
        Artifact("stem_bwd",
                 lambda p, x, g: cnn_stem_bwd(c, p, x, g),
                 [tuple(_param_sds(stem_specs)), img, _sds(act_in[0])],
                 names(stem_specs) + ["x", "grad_in"],
                 names(stem_specs, "g_") + ["g_x"]),
    ]
    for i in range(len(ch)):
        specs = cnn_block_specs(c, i)
        xin = _sds(act_in[i])
        hwo = act_in[i][1] // 2
        xout = _sds((B, hwo, hwo, ch[i]))
        arts.append(Artifact(
            f"block{i}_fwd",
            lambda p, x, i=i: (cnn_block_fwd(c, i, p, x),),
            [tuple(_param_sds(specs)), xin],
            names(specs) + ["x"], ["act"]))
        arts.append(Artifact(
            f"block{i}_bwd",
            lambda p, x, g, i=i: cnn_block_bwd(c, i, p, x, g),
            [tuple(_param_sds(specs)), xin, xout],
            names(specs) + ["x", "grad_in"],
            names(specs, "g_") + ["g_x"]))
    arts.append(Artifact(
        "head_fwdbwd",
        lambda p, x, t: cnn_head_fwdbwd(c, p, x, t),
        [tuple(_param_sds(head_specs)), _sds(head_in), lbl],
        names(head_specs) + ["x", "labels"],
        ["loss"] + names(head_specs, "g_") + ["g_x"]))
    arts.append(Artifact(
        "head_loss",
        lambda p, x, t: (cnn_head_loss(c, p, x, t),),
        [tuple(_param_sds(head_specs)), _sds(head_in), lbl],
        names(head_specs) + ["x", "labels"], ["loss"]))
    return arts
