"""AOT compile path: lower every stage computation to HLO text + manifest.

Run once at build time (``make artifacts``).  Python never appears on the
training hot path: the Rust coordinator loads ``artifacts/<model>/*.hlo.txt``
through ``HloModuleProto::from_text_file`` and executes them on the PJRT
CPU client.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly.

The manifest (``artifacts/manifest.json``) tells the Rust side everything
it needs: per-model configuration, the logical layer sequence with
parameter specs / FLOPs / activation + weight bytes (planner inputs), and
per-artifact flattened input/output signatures (runtime inputs).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

FLOAT_BYTES = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "s32", "uint32": "u32"}[jnp.dtype(dt).name]


def _flatten_args(args) -> list:
    flat, _ = jax.tree_util.tree_flatten(args)
    return flat


def lower_artifact(art: M.Artifact, out_dir: pathlib.Path) -> dict:
    """Lower one artifact; return its manifest entry."""
    t0 = time.time()
    # keep_unused=True: the Rust runtime passes every manifest input, so
    # arguments whose *values* the computation doesn't need (e.g. a bias
    # in its own VJP) must stay in the HLO parameter list.
    lowered = jax.jit(art.fn, keep_unused=True).lower(*art.args)
    text = to_hlo_text(lowered)
    path = out_dir / f"{art.name}.hlo.txt"
    path.write_text(text)

    flat_in = _flatten_args(art.args)
    assert len(flat_in) == len(art.arg_names), (
        f"{art.name}: {len(flat_in)} args vs {len(art.arg_names)} names")
    outs = jax.eval_shape(art.fn, *art.args)
    flat_out = _flatten_args(outs)
    assert len(flat_out) == len(art.out_names), (
        f"{art.name}: {len(flat_out)} outs vs {len(art.out_names)} names")

    entry = {
        "file": f"{out_dir.name}/{art.name}.hlo.txt",
        "inputs": [
            {"name": n, "shape": list(a.shape), "dtype": _dtype_str(a.dtype)}
            for n, a in zip(art.arg_names, flat_in)
        ],
        "outputs": [
            {"name": n, "shape": list(a.shape), "dtype": _dtype_str(a.dtype)}
            for n, a in zip(art.out_names, flat_out)
        ],
    }
    print(f"  lowered {art.name:<14} {len(text):>9} chars "
          f"({time.time() - t0:.1f}s)")
    return entry


# --------------------------------------------------------------------------
# Per-layer planner metadata (FLOPs / bytes) — mirrors the Asteroid
# profiler's `a_l`, `w_l` and feeds the Rust planner for the real models.
# --------------------------------------------------------------------------

def _weight_bytes(specs) -> int:
    total = 0
    for s in specs:
        n = 1
        for d in s.shape:
            n *= d
        total += n * FLOAT_BYTES
    return total


def _lm_layers(c: M.LMConfig) -> list:
    B, S, D, F, V = c.microbatch, c.seq, c.d_model, c.d_ff, c.vocab
    act_bytes = B * S * D * FLOAT_BYTES
    block_fwd_flops = (
        4 * 2 * B * S * D * D       # q, k, v, o projections
        + 2 * 2 * B * S * S * D     # scores + context
        + 2 * 2 * B * S * D * F     # FFN up + down
    )
    layers = [{
        "name": "embed", "kind": "embed",
        "params": [p.to_json() for p in M.lm_embed_specs(c)],
        "weight_bytes": _weight_bytes(M.lm_embed_specs(c)),
        "out_bytes": act_bytes,
        "flops_fwd": 2 * B * S * D,           # add + lookup traffic
        "flops_bwd": 4 * B * S * D,
        "artifact_fwd": "embed_fwd", "artifact_bwd": "embed_bwd",
    }]
    for i in range(c.n_blocks):
        layers.append({
            "name": f"block{i}", "kind": "block",
            "params": [p.to_json() for p in M.lm_block_specs(c)],
            "weight_bytes": _weight_bytes(M.lm_block_specs(c)),
            "out_bytes": act_bytes,
            "flops_fwd": block_fwd_flops,
            "flops_bwd": 2 * block_fwd_flops,
            "artifact_fwd": "block_fwd", "artifact_bwd": "block_bwd",
        })
    layers.append({
        "name": "head", "kind": "head",
        "params": [p.to_json() for p in M.lm_head_specs(c)],
        "weight_bytes": _weight_bytes(M.lm_head_specs(c)),
        "out_bytes": 0,
        "flops_fwd": 2 * B * S * D * V,
        "flops_bwd": 4 * B * S * D * V,
        "artifact_fwd": "head_fwdbwd", "artifact_bwd": "head_fwdbwd",
    })
    return layers


def _cnn_layers(c: M.CNNConfig) -> list:
    B, HW = c.microbatch, c.hw
    ch = c.channels

    def conv_flops(hw, cin, cout):
        return 2 * B * hw * hw * 9 * cin * cout

    layers = [{
        "name": "stem", "kind": "stem",
        "params": [p.to_json() for p in M.cnn_stem_specs(c)],
        "weight_bytes": _weight_bytes(M.cnn_stem_specs(c)),
        "out_bytes": B * HW * HW * ch[0] * FLOAT_BYTES,
        "flops_fwd": conv_flops(HW, c.in_ch, ch[0]),
        "flops_bwd": 2 * conv_flops(HW, c.in_ch, ch[0]),
        "artifact_fwd": "stem_fwd", "artifact_bwd": "stem_bwd",
    }]
    hw = HW
    for i in range(len(ch)):
        cin = ch[0] if i == 0 else ch[i - 1]
        specs = M.cnn_block_specs(c, i)
        flops = conv_flops(hw, cin, ch[i]) + conv_flops(hw, ch[i], ch[i])
        hw //= 2
        layers.append({
            "name": f"block{i}", "kind": f"block{i}",
            "params": [p.to_json() for p in specs],
            "weight_bytes": _weight_bytes(specs),
            "out_bytes": B * hw * hw * ch[i] * FLOAT_BYTES,
            "flops_fwd": flops,
            "flops_bwd": 2 * flops,
            "artifact_fwd": f"block{i}_fwd", "artifact_bwd": f"block{i}_bwd",
        })
    layers.append({
        "name": "head", "kind": "head",
        "params": [p.to_json() for p in M.cnn_head_specs(c)],
        "weight_bytes": _weight_bytes(M.cnn_head_specs(c)),
        "out_bytes": 0,
        "flops_fwd": 2 * B * ch[-1] * c.classes,
        "flops_bwd": 4 * B * ch[-1] * c.classes,
        "artifact_fwd": "head_fwdbwd", "artifact_bwd": "head_fwdbwd",
    })
    return layers


LM_PRESETS = {
    "lm": M.LMConfig(),
    "lm-base": M.LMConfig(vocab=512, d_model=256, n_heads=8, d_ff=1024,
                          seq=128, n_blocks=8),
}


def build_model(name: str, out_root: pathlib.Path, backend: str) -> dict:
    out_dir = out_root / name
    out_dir.mkdir(parents=True, exist_ok=True)
    print(f"model {name}:")
    if name.startswith("lm"):
        cfg = LM_PRESETS[name]
        arts = M.lm_artifacts(cfg, backend)
        layers = _lm_layers(cfg)
        config = {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "seq": cfg.seq,
            "n_blocks": cfg.n_blocks, "microbatch": cfg.microbatch,
        }
        kind = "transformer"
    elif name == "cnn":
        cfg = M.CNNConfig()
        arts = M.cnn_artifacts(cfg)
        layers = _cnn_layers(cfg)
        config = {
            "hw": cfg.hw, "in_ch": cfg.in_ch,
            "channels": list(cfg.channels), "classes": cfg.classes,
            "microbatch": cfg.microbatch,
        }
        kind = "cnn"
    else:
        raise ValueError(f"unknown model {name!r}")

    artifacts = {a.name: lower_artifact(a, out_dir) for a in arts}
    return {
        "kind": kind,
        "config": config,
        "microbatch": config["microbatch"],
        "layers": layers,
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--models", default="lm,cnn",
                    help="comma list from {lm, lm-base, cnn}")
    ap.add_argument("--backend", default="pallas", choices=["pallas", "ref"],
                    help="kernel backend lowered into the HLO")
    args = ap.parse_args()

    out_root = pathlib.Path(args.out)
    out_root.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "backend": args.backend,
        "models": {},
    }
    for name in args.models.split(","):
        manifest["models"][name.strip()] = build_model(
            name.strip(), out_root, args.backend)

    (out_root / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_root}/manifest.json ({time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    main()
