"""Layer-1 Pallas kernel: fused causal self-attention forward.

The paper's transformer workload (Bert-small) spends its stage time in
attention; on the Jetson GPUs this is a chain of cuBLAS calls with the
score matrix round-tripping through HBM.  The TPU re-think keeps the
whole ``scores -> softmax -> context`` chain for one query row-block in
VMEM:

  * grid is ``(batch*heads, Sq/bq)``; each step owns a ``(bq, hd)`` query
    block plus the full ``(Skv, hd)`` K and V panels for that head
    (sequence lengths here are small enough that K/V fit VMEM; for long
    sequences the same kernel extends with a KV grid axis and online
    softmax);
  * the causal mask is materialised with ``iota`` inside the kernel — no
    HBM mask tensor;
  * softmax is computed in f32 regardless of the input dtype.

The backward pass recomputes attention from the residuals with plain
jnp (rematerialisation) — it lowers into the same stage HLO, and keeps
the paper's Eq.(3) activation accounting (only stage *inputs* are
stashed between forward and backward).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                 bq: int):
    """One (head, query-block) step: fused QK^T -> masked softmax -> PV."""
    q = q_ref[0].astype(jnp.float32)  # (bq, hd)   — leading head axis is 1
    k = k_ref[0].astype(jnp.float32)  # (skv, hd)
    v = v_ref[0].astype(jnp.float32)  # (skv, hd)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qi = pl.program_id(1)
        row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(col <= row, scores, jnp.finfo(jnp.float32).min)
    # Numerically-stable softmax, fully in registers/VMEM.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = (jnp.dot(p, v, preferred_element_type=jnp.float32) / denom
                ).astype(o_ref.dtype)


def attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, bq: int | None = None) -> jax.Array:
    """Fused attention over ``(B, H, S, hd)`` operands."""
    b, h, sq, hd = q.shape
    _, _, skv, _ = k.shape
    if k.shape != (b, h, skv, hd) or v.shape != (b, h, skv, hd):
        raise ValueError(f"shape mismatch: q={q.shape} k={k.shape} v={v.shape}")
    bq = bq or pick_block(sq, 128)
    scale = 1.0 / float(hd) ** 0.5
    qr = q.reshape(b * h, sq, hd)
    kr = k.reshape(b * h, skv, hd)
    vr = v.reshape(b * h, skv, hd)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal, bq=bq),
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, skv, hd), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, skv, hd), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        interpret=True,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, hd)


def _attn_ref_f32(q, k, v, causal):
    """jnp reference used for the recompute backward (f32 math)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if causal:
        sq, skv = scores.shape[-2:]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True) -> jax.Array:
    """Differentiable fused attention (recompute backward)."""
    return attention_pallas(q, k, v, causal=causal)


def _attention_fwd(q, k, v, causal):
    return attention_pallas(q, k, v, causal=causal), (q, k, v)


def _attention_bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _attn_ref_f32(q_, k_, v_, causal), q, k, v)
    dq, dk, dv = vjp(g.astype(jnp.float32))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


attention.defvjp(_attention_fwd, _attention_bwd)


def vmem_bytes(sq: int, skv: int, hd: int, bq: int | None = None,
               bytes_per_el: int = 4) -> int:
    """VMEM resident estimate per grid step: q block, K, V panels, score
    block and output block.  Reported in EXPERIMENTS.md §Perf."""
    bq = bq or pick_block(sq, 128)
    return (bq * hd + 2 * skv * hd + bq * skv + bq * hd) * bytes_per_el
