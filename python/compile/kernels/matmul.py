"""Layer-1 Pallas kernel: MXU-tiled block matmul with a custom VJP.

This is Asteroid's compute hot-spot (the dense matmuls in the FFN and
attention projections of every pipeline stage).  The paper executes these
on Jetson CUDA cores; we re-think the blocking for TPU (see DESIGN.md
§Hardware-Adaptation-L1):

  * the grid is ``(M/bm, N/bn, K/bk)`` with the K dimension innermost so
    each output block stays resident while K-panels stream through VMEM —
    the declarative analogue of a CUDA shared-memory tile loop;
  * the inner ``jnp.dot`` on ``(bm, bk) x (bk, bn)`` blocks with
    ``preferred_element_type=float32`` maps directly onto the 128x128 MXU
    systolic array when ``bm = bn = bk = 128``;
  * the backward pass needs no second kernel: ``dx = g @ W^T`` and
    ``dW = x^T @ g`` are themselves matmuls and reuse this kernel.

Kernels are lowered with ``interpret=True`` so the emitted HLO runs on the
CPU PJRT client (real-TPU Mosaic custom-calls are not CPU-executable);
the blocking structure is what we optimize and report in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly target block edges.  The systolic array is 128x128, but
# larger blocks amortise per-grid-step overhead (double-buffering setup,
# and in interpret mode the dynamic-slice plumbing: raising the M target
# from 128 to 512 cut kernel wall-clock 3.3x on the CPU substrate — see
# EXPERIMENTS.md §Perf) while staying far under the ~16 MiB VMEM budget:
# a (512, 256) x (256, 256) step keeps 1.3 MiB resident.
MXU_BLOCK = 128
BLOCK_M = 512
BLOCK_N = 256
BLOCK_K = 256


def pick_block(dim: int, target: int = MXU_BLOCK) -> int:
    """Largest divisor of ``dim`` that is <= ``target``.

    Guarantees the grid tiles the operand exactly (Pallas blocks must
    cover the array; we avoid masked edge blocks entirely).
    """
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return 1  # unreachable: 1 divides everything


def _mm_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One grid step: accumulate an (bm, bk) x (bk, bn) panel product.

    The output block is revisited for every k; it doubles as the f32
    accumulator (initialised at k == 0), which avoids a scratch buffer
    and keeps VMEM usage to bm*bk + bk*bn + bm*bn floats per step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def matmul_pallas(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """Tiled ``x @ y`` (2-D only).  Output dtype is float32."""
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {x.shape} @ {y.shape}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    bm = bm or pick_block(m, BLOCK_M)
    bn = bn or pick_block(n, BLOCK_N)
    bk = bk or pick_block(k, BLOCK_K)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"blocks ({bm},{bn},{bk}) must divide dims ({m},{n},{k})")
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable tiled matmul; fwd and both grads use the same kernel."""
    return matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dgrad and wgrad are matmuls too: reuse the tiled kernel.
    dx = matmul_pallas(g, y.T)
    dy = matmul_pallas(x.T, g)
    return dx.astype(x.dtype), dy.astype(y.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(m: int, n: int, k: int, bm: int | None = None,
               bn: int | None = None, bk: int | None = None,
               bytes_per_el: int = 4) -> int:
    """Estimated VMEM resident bytes per grid step (x, y, acc blocks).

    Used by the §Perf analysis: on a real TPU this must stay under the
    ~16 MiB VMEM budget; the default 128^3 blocking uses 192 KiB.
    """
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)
    return (bm * bk + bk * bn + bm * bn) * bytes_per_el


def mxu_utilization(m: int, n: int, k: int, bm: int | None = None,
                    bn: int | None = None, bk: int | None = None) -> float:
    """Fraction of the MXU's 128x128x8-per-cycle capacity the inner dot
    can keep busy, estimated from block geometry (1.0 when all block
    edges are multiples of the 128-wide systolic array)."""
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)
    eff = 1.0
    for edge in (bm, bn, bk):
        lanes = -(-edge // 128) * 128  # systolic passes are 128-wide
        eff *= edge / lanes
    return eff
