"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest + hypothesis sweep shapes
and dtypes and assert the Pallas outputs match these references.  They
are also used as a drop-in kernel backend (``model.py`` with
``backend="ref"``) so stage-level numerics can be separated from
kernel-level numerics when debugging.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain f32 matmul, the oracle for kernels.matmul."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """Plain causal attention over (B, H, S, hd), f32 softmax."""
    hd = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    if causal:
        sq, skv = scores.shape[-2:]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    """Plain layernorm over the last axis of a 2-D input."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    return ((xf - mean) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)
