"""Asteroid Layer-1 Pallas kernels (build-time only; lowered into stage HLO)."""

from .matmul import matmul, matmul_pallas, pick_block
from .attention import attention, attention_pallas
from .layernorm import layernorm, layernorm_pallas
from . import ref

__all__ = [
    "matmul", "matmul_pallas", "pick_block",
    "attention", "attention_pallas",
    "layernorm", "layernorm_pallas",
    "ref",
]
