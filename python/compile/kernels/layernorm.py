"""Layer-1 Pallas kernel: fused LayerNorm forward over row blocks.

Normalisation is memory-bound; the fused kernel reads each row once from
HBM into VMEM, computes mean/variance/scale/shift in one pass and writes
the row back — versus three HBM passes for the unfused mean/var/apply
chain.  Rows are processed in blocks of ``br`` so arbitrarily many rows
stream through a fixed VMEM footprint.

Backward recomputes statistics from the stashed inputs with jnp
(rematerialisation), matching the stage-input-only activation accounting
of the paper's Eq. (3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _ln_kernel(x_ref, scale_ref, bias_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, d)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mean) * inv * scale_ref[...] + bias_ref[...]
    o_ref[...] = y.astype(o_ref.dtype)


def layernorm_pallas(x: jax.Array, scale: jax.Array, bias: jax.Array, *,
                     eps: float = 1e-5, br: int | None = None) -> jax.Array:
    """Fused layernorm over the last axis of a 2-D ``(rows, d)`` input."""
    if x.ndim != 2:
        raise ValueError(f"layernorm_pallas expects 2-D input, got {x.shape}")
    rows, d = x.shape
    if scale.shape != (d,) or bias.shape != (d,):
        raise ValueError(f"scale/bias must be ({d},), got {scale.shape}/{bias.shape}")
    br = br or pick_block(rows, 512)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, scale, bias)


def _ln_ref(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    return ((xf - mean) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    """Differentiable fused layernorm (recompute backward)."""
    return layernorm_pallas(x, scale, bias, eps=eps)


def _layernorm_fwd(x, scale, bias, eps):
    return layernorm_pallas(x, scale, bias, eps=eps), (x, scale, bias)


def _layernorm_bwd(eps, res, g):
    x, scale, bias = res
    _, vjp = jax.vjp(lambda x_, s_, b_: _ln_ref(x_, s_, b_, eps), x, scale, bias)
    dx, ds, db = vjp(g)
    return dx, ds, db


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)


def vmem_bytes(rows: int, d: int, br: int | None = None,
               bytes_per_el: int = 4) -> int:
    """VMEM resident estimate per grid step (input + output row blocks
    plus the scale/bias vectors)."""
    br = br or pick_block(rows, 128)
    return (2 * br * d + 2 * d) * bytes_per_el
