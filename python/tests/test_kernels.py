"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes/dtypes of every Pallas kernel and asserts
allclose against the pure-jnp oracles in ``kernels/ref.py``, including
through ``jax.grad`` (the custom VJPs).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.matmul import pick_block, vmem_bytes, mxu_utilization

jax.config.update("jax_enable_x64", False)

dims = functools.partial(st.integers, min_value=1)


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------------------
# pick_block
# --------------------------------------------------------------------------

@given(dim=dims(max_value=2048), target=st.sampled_from([8, 32, 64, 128]))
@settings(max_examples=200, deadline=None)
def test_pick_block_divides_and_bounded(dim, target):
    b = pick_block(dim, target)
    assert dim % b == 0
    assert b <= max(target, 1) or dim <= target
    if dim <= target:
        assert b == dim


def test_pick_block_prefers_largest_divisor():
    assert pick_block(256, 128) == 128
    assert pick_block(136, 128) == 68
    assert pick_block(8, 128) == 8
    assert pick_block(97, 64) == 1  # prime > target


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

MM_SHAPES = st.tuples(
    st.sampled_from([8, 16, 24, 40, 64, 128, 136, 192]),
    st.sampled_from([8, 16, 32, 48, 64, 128, 160]),
    st.sampled_from([8, 16, 32, 56, 64, 128]),
)


@given(shape=MM_SHAPES, seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_matmul_matches_ref(shape, seed):
    m, k, n = shape
    x = _rand(seed, (m, k), jnp.float32)
    y = _rand(seed + 1, (k, n), jnp.float32)
    out = kernels.matmul_pallas(x, y)
    np.testing.assert_allclose(out, ref.ref_matmul(x, y), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand(0, (64, 64), dtype)
    y = _rand(1, (64, 64), dtype)
    out = kernels.matmul_pallas(x, y)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.ref_matmul(x, y), np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_matmul_custom_block_sizes():
    x = _rand(0, (128, 96), jnp.float32)
    y = _rand(1, (96, 64), jnp.float32)
    for bm, bn, bk in [(32, 32, 32), (64, 64, 48), (128, 64, 96)]:
        out = kernels.matmul_pallas(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(out, ref.ref_matmul(x, y),
                                   rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_blocks():
    x = _rand(0, (64, 64), jnp.float32)
    with pytest.raises(ValueError):
        kernels.matmul_pallas(x, x, bm=48)
    with pytest.raises(ValueError):
        kernels.matmul_pallas(x, _rand(1, (32, 64), jnp.float32))
    with pytest.raises(ValueError):
        kernels.matmul_pallas(x.reshape(4, 16, 64), x)


@given(seed=st.integers(0, 2**16),
       shape=st.sampled_from([(16, 32, 24), (64, 64, 64), (40, 8, 48)]))
@settings(max_examples=10, deadline=None)
def test_matmul_grad_matches_ref(seed, shape):
    m, k, n = shape
    x = _rand(seed, (m, k), jnp.float32)
    y = _rand(seed + 7, (k, n), jnp.float32)

    def f_pal(x, y):
        return jnp.sum(jnp.sin(kernels.matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(ref.ref_matmul(x, y)))

    gx_p, gy_p = jax.grad(f_pal, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy_p, gy_r, rtol=1e-4, atol=1e-4)


def test_matmul_under_jit():
    x = _rand(3, (64, 64), jnp.float32)
    out = jax.jit(kernels.matmul)(x, x)
    np.testing.assert_allclose(out, ref.ref_matmul(x, x), rtol=1e-5, atol=1e-5)


def test_matmul_vmem_estimate_default_blocking():
    # 128^3 blocking: 3 blocks of 128x128 f32 = 192 KiB, well under VMEM.
    assert vmem_bytes(1024, 1024, 1024) == 3 * 128 * 128 * 4
    assert vmem_bytes(1024, 1024, 1024) < 16 * 1024 * 1024


def test_mxu_utilization_bounds():
    assert mxu_utilization(128, 128, 128) == 1.0
    assert 0.0 < mxu_utilization(8, 8, 8) < 0.1
    assert mxu_utilization(256, 256, 256) == 1.0


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

ATT_SHAPES = st.tuples(
    st.sampled_from([1, 2, 4]),          # batch
    st.sampled_from([1, 2, 4]),          # heads
    st.sampled_from([8, 16, 64, 128]),   # seq
    st.sampled_from([8, 16, 32, 64]),    # head dim
)


@given(shape=ATT_SHAPES, causal=st.booleans(), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_attention_matches_ref(shape, causal, seed):
    b, h, s, hd = shape
    q = _rand(seed, (b, h, s, hd), jnp.float32)
    k = _rand(seed + 1, (b, h, s, hd), jnp.float32)
    v = _rand(seed + 2, (b, h, s, hd), jnp.float32)
    out = kernels.attention_pallas(q, k, v, causal=causal)
    np.testing.assert_allclose(
        out, ref.ref_attention(q, k, v, causal), rtol=1e-5, atol=1e-5)


def test_attention_causality():
    """Future positions must not influence earlier outputs."""
    b, h, s, hd = 1, 1, 16, 8
    q = _rand(0, (b, h, s, hd), jnp.float32)
    k = _rand(1, (b, h, s, hd), jnp.float32)
    v = _rand(2, (b, h, s, hd), jnp.float32)
    base = kernels.attention_pallas(q, k, v, causal=True)
    k2 = k.at[:, :, -1].set(99.0)
    v2 = v.at[:, :, -1].set(-99.0)
    pert = kernels.attention_pallas(q, k2, v2, causal=True)
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[:, :, -1], pert[:, :, -1])


def test_attention_rows_are_convex_combinations():
    """Each output row lies in the convex hull of V rows (softmax weights)."""
    q = _rand(0, (1, 2, 32, 16), jnp.float32)
    k = _rand(1, (1, 2, 32, 16), jnp.float32)
    v = jnp.abs(_rand(2, (1, 2, 32, 16), jnp.float32))
    out = kernels.attention_pallas(q, k, v, causal=False)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-5
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-5


def test_attention_grad_matches_ref():
    b, h, s, hd = 2, 2, 16, 8
    q = _rand(0, (b, h, s, hd), jnp.float32)
    k = _rand(1, (b, h, s, hd), jnp.float32)
    v = _rand(2, (b, h, s, hd), jnp.float32)

    def f_pal(q, k, v):
        return jnp.sum(kernels.attention(q, k, v, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.ref_attention(q, k, v, True) ** 2)

    gp = jax.grad(f_pal, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_attention_shape_mismatch_raises():
    q = _rand(0, (1, 2, 16, 8), jnp.float32)
    k = _rand(1, (1, 2, 16, 8), jnp.float32)
    v = _rand(2, (1, 2, 8, 8), jnp.float32)  # skv disagrees with k
    with pytest.raises(ValueError):
        kernels.attention_pallas(q, k, v)
    with pytest.raises(ValueError):  # head count disagrees
        kernels.attention_pallas(q, _rand(3, (1, 1, 16, 8), jnp.float32), k)


# --------------------------------------------------------------------------
# layernorm
# --------------------------------------------------------------------------

LN_SHAPES = st.tuples(
    st.sampled_from([8, 16, 64, 128, 256]),   # rows
    st.sampled_from([8, 16, 32, 128, 192]),   # features
)


@given(shape=LN_SHAPES, seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_layernorm_matches_ref(shape, seed):
    rows, d = shape
    x = _rand(seed, (rows, d), jnp.float32) * 3.0 + 1.5
    scale = _rand(seed + 1, (d,), jnp.float32)
    bias = _rand(seed + 2, (d,), jnp.float32)
    out = kernels.layernorm_pallas(x, scale, bias)
    np.testing.assert_allclose(out, ref.ref_layernorm(x, scale, bias),
                               rtol=1e-5, atol=1e-5)


def test_layernorm_normalizes():
    x = _rand(0, (32, 64), jnp.float32) * 10 + 4
    out = kernels.layernorm_pallas(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.mean(out, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(out, -1), 1.0, atol=1e-3)


def test_layernorm_grad_matches_ref():
    x = _rand(0, (16, 32), jnp.float32)
    s = jnp.ones(32) * 1.3
    b = jnp.zeros(32) + 0.2

    def f_pal(x, s, b):
        return jnp.sum(kernels.layernorm(x, s, b) ** 3)

    def f_ref(x, s, b):
        return jnp.sum(ref.ref_layernorm(x, s, b) ** 3)

    gp = jax.grad(f_pal, argnums=(0, 1, 2))(x, s, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, s, b)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_layernorm_rejects_bad_shapes():
    x = _rand(0, (16, 32), jnp.float32)
    with pytest.raises(ValueError):
        kernels.layernorm_pallas(x.reshape(2, 8, 32), jnp.ones(32), jnp.zeros(32))
    with pytest.raises(ValueError):
        kernels.layernorm_pallas(x, jnp.ones(16), jnp.zeros(32))
