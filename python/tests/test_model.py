"""L2 stage-model correctness.

Validates that the per-stage artifacts (embed/block/head fwd + bwd)
compose to exactly the gradients of end-to-end autodiff on the full
model — the property the Rust pipeline engine relies on — and that the
pallas and ref kernel backends agree at the model level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.LMConfig(vocab=64, d_model=32, n_heads=2, d_ff=64, seq=16,
                 n_blocks=2, microbatch=2)
CNN = M.CNNConfig(hw=16, channels=(8, 16, 16), classes=10, microbatch=2)


def _lm_params(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    ke, kb, kh = jax.random.split(key, 3)
    embed = M.init_params(M.lm_embed_specs(cfg), ke)
    blocks = tuple(
        M.init_params(M.lm_block_specs(cfg), jax.random.fold_in(kb, i))
        for i in range(cfg.n_blocks))
    head = M.init_params(M.lm_head_specs(cfg), kh)
    return embed, blocks, head


def _lm_batch(cfg, seed=1):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (cfg.microbatch, cfg.seq), 0, cfg.vocab)
    targets = jnp.roll(toks, -1, axis=1)
    return toks, targets


class TestLMStageComposition:
    def test_forward_composes(self):
        """embed_fwd ∘ block_fwd^n ∘ head_loss == full model loss."""
        params = _lm_params(CFG)
        toks, tgts = _lm_batch(CFG)
        embed_p, block_ps, head_p = params
        h = M.lm_embed_fwd(CFG, embed_p, toks)
        for bp in block_ps:
            h = M.lm_block_fwd(CFG, bp, h, "ref")
        loss_stage = M.lm_head_loss(CFG, head_p, h, tgts, "ref")
        loss_full = M.lm_full_loss(CFG, params, toks, tgts, "ref")
        np.testing.assert_allclose(loss_stage, loss_full, rtol=1e-6)

    def test_staged_backward_matches_full_autodiff(self):
        """Chaining head_fwdbwd -> block_bwd -> embed_bwd reproduces
        jax.grad of the composed model — the pipeline BP contract."""
        params = _lm_params(CFG)
        toks, tgts = _lm_batch(CFG)
        embed_p, block_ps, head_p = params

        # Reference: end-to-end autodiff.
        ref_grads = jax.grad(
            lambda p: M.lm_full_loss(CFG, p, toks, tgts, "ref"))(params)
        ref_embed_g, ref_block_gs, ref_head_g = ref_grads

        # Staged: forward saving stage inputs, then backward chain.
        acts = [M.lm_embed_fwd(CFG, embed_p, toks)]
        for bp in block_ps:
            acts.append(M.lm_block_fwd(CFG, bp, acts[-1], "ref"))

        out = M.lm_head_fwdbwd(CFG, head_p, acts[-1], tgts, "ref")
        loss, head_gs, gx = out[0], out[1:-1], out[-1]
        for hg, rg in zip(head_gs, ref_head_g):
            np.testing.assert_allclose(hg, rg, rtol=1e-4, atol=1e-5)

        for i in reversed(range(CFG.n_blocks)):
            out = M.lm_block_bwd(CFG, block_ps[i], acts[i], gx, "ref")
            block_gs, gx = out[:-1], out[-1]
            for bg, rg in zip(block_gs, ref_block_gs[i]):
                np.testing.assert_allclose(bg, rg, rtol=1e-4, atol=1e-5)

        embed_gs = M.lm_embed_bwd(CFG, embed_p, toks, gx)
        for eg, rg in zip(embed_gs, ref_embed_g):
            np.testing.assert_allclose(eg, rg, rtol=1e-4, atol=1e-5)

    def test_pallas_backend_matches_ref_backend(self):
        params = _lm_params(CFG)
        toks, tgts = _lm_batch(CFG)
        l_ref = M.lm_full_loss(CFG, params, toks, tgts, "ref")
        l_pal = M.lm_full_loss(CFG, params, toks, tgts, "pallas")
        np.testing.assert_allclose(l_pal, l_ref, rtol=1e-5, atol=1e-6)

    def test_pallas_grads_match_ref(self):
        params = _lm_params(CFG)
        toks, tgts = _lm_batch(CFG)
        g_ref = jax.grad(lambda p: M.lm_full_loss(CFG, p, toks, tgts, "ref"))(params)
        g_pal = jax.grad(lambda p: M.lm_full_loss(CFG, p, toks, tgts, "pallas"))(params)
        flat_r, _ = jax.tree_util.tree_flatten(g_ref)
        flat_p, _ = jax.tree_util.tree_flatten(g_pal)
        for a, b in zip(flat_p, flat_r):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)

    def test_loss_decreases_under_sgd(self):
        """Sanity: a few SGD steps on one batch reduce the loss — the
        property the Rust optimizer path depends on."""
        params = _lm_params(CFG)
        toks, tgts = _lm_batch(CFG)
        loss_fn = jax.jit(lambda p: M.lm_full_loss(CFG, p, toks, tgts, "ref"))
        grad_fn = jax.jit(jax.grad(lambda p: M.lm_full_loss(CFG, p, toks, tgts, "ref")))
        l0 = float(loss_fn(params))
        for _ in range(5):
            g = grad_fn(params)
            params = jax.tree_util.tree_map(lambda p, g_: p - 0.5 * g_, params, g)
        l1 = float(loss_fn(params))
        assert l1 < l0, f"loss did not decrease: {l0} -> {l1}"

    def test_block_bwd_output_arity(self):
        params = _lm_params(CFG)
        _, block_ps, _ = params
        x = jnp.zeros((CFG.microbatch, CFG.seq, CFG.d_model))
        out = M.lm_block_bwd(CFG, block_ps[0], x, x, "ref")
        assert len(out) == len(M.lm_block_specs(CFG)) + 1
        assert out[-1].shape == x.shape


class TestCNNStageComposition:
    def _params(self, seed=0):
        key = jax.random.PRNGKey(seed)
        ks, kb, kh = jax.random.split(key, 3)
        stem = M.init_params(M.cnn_stem_specs(CNN), ks)
        blocks = tuple(
            M.init_params(M.cnn_block_specs(CNN, i), jax.random.fold_in(kb, i))
            for i in range(len(CNN.channels)))
        head = M.init_params(M.cnn_head_specs(CNN), kh)
        return stem, blocks, head

    def _batch(self, seed=1):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (CNN.microbatch, CNN.hw, CNN.hw, CNN.in_ch))
        y = jax.random.randint(jax.random.fold_in(key, 1), (CNN.microbatch,),
                               0, CNN.classes)
        return x, y

    def test_forward_composes(self):
        params = self._params()
        x, y = self._batch()
        stem_p, block_ps, head_p = params
        h = M.cnn_stem_fwd(CNN, stem_p, x)
        for i, bp in enumerate(block_ps):
            h = M.cnn_block_fwd(CNN, i, bp, h)
        np.testing.assert_allclose(
            M.cnn_head_loss(CNN, head_p, h, y),
            M.cnn_full_loss(CNN, params, x, y), rtol=1e-6)

    def test_staged_backward_matches_full_autodiff(self):
        params = self._params()
        x, y = self._batch()
        stem_p, block_ps, head_p = params
        ref_grads = jax.grad(lambda p: M.cnn_full_loss(CNN, p, x, y))(params)
        ref_stem_g, ref_block_gs, ref_head_g = ref_grads

        acts = [M.cnn_stem_fwd(CNN, stem_p, x)]
        for i, bp in enumerate(block_ps):
            acts.append(M.cnn_block_fwd(CNN, i, bp, acts[-1]))

        out = M.cnn_head_fwdbwd(CNN, head_p, acts[-1], y)
        _, head_gs, gx = out[0], out[1:-1], out[-1]
        for hg, rg in zip(head_gs, ref_head_g):
            np.testing.assert_allclose(hg, rg, rtol=1e-4, atol=1e-5)
        for i in reversed(range(len(block_ps))):
            out = M.cnn_block_bwd(CNN, i, block_ps[i], acts[i], gx)
            block_gs, gx = out[:-1], out[-1]
            for bg, rg in zip(block_gs, ref_block_gs[i]):
                np.testing.assert_allclose(bg, rg, rtol=1e-4, atol=1e-5)
        out = M.cnn_stem_bwd(CNN, stem_p, x, gx)
        for sg, rg in zip(out[:-1], ref_stem_g):
            np.testing.assert_allclose(sg, rg, rtol=1e-4, atol=1e-5)

    def test_block_shapes_halve(self):
        params = self._params()
        x, _ = self._batch()
        h = M.cnn_stem_fwd(CNN, params[0], x)
        assert h.shape == (CNN.microbatch, CNN.hw, CNN.hw, CNN.channels[0])
        hw = CNN.hw
        for i, bp in enumerate(params[1]):
            h = M.cnn_block_fwd(CNN, i, bp, h)
            hw //= 2
            assert h.shape == (CNN.microbatch, hw, hw, CNN.channels[i])


class TestArtifactRegistry:
    def test_lm_artifact_arg_names_match_flatten(self):
        arts = M.lm_artifacts(CFG, "ref")
        names = {a.name for a in arts}
        assert names == {"embed_fwd", "embed_bwd", "block_fwd", "block_bwd",
                         "head_fwdbwd", "head_loss"}
        for a in arts:
            flat, _ = jax.tree_util.tree_flatten(a.args)
            assert len(flat) == len(a.arg_names), a.name

    def test_lm_artifact_output_arity(self):
        for a in M.lm_artifacts(CFG, "ref"):
            outs = jax.eval_shape(a.fn, *a.args)
            flat, _ = jax.tree_util.tree_flatten(outs)
            assert len(flat) == len(a.out_names), a.name

    def test_cnn_artifact_shapes_consistent(self):
        for a in M.cnn_artifacts(CNN):
            outs = jax.eval_shape(a.fn, *a.args)
            flat, _ = jax.tree_util.tree_flatten(outs)
            assert len(flat) == len(a.out_names), a.name

    def test_artifact_fns_execute(self):
        """Each artifact fn runs on concrete zeros without error."""
        for a in M.lm_artifacts(CFG, "ref"):
            flat, treedef = jax.tree_util.tree_flatten(a.args)
            concrete = [jnp.zeros(s.shape, s.dtype) for s in flat]
            args = jax.tree_util.tree_unflatten(treedef, concrete)
            a.fn(*args)
