//! End-to-end CNN training: the vision counterpart of e2e_train_lm —
//! the CIFAR-style CNN through a planner-chosen hybrid pipeline.
//!
//!     cargo run --release --example e2e_train_cnn [steps]

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::coordinator::Coordinator;
use asteroid::data::VisionTask;
use asteroid::metrics::Table;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::{OptimizerCfg, TrainOpts};

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(60);
    let artifacts = std::path::PathBuf::from("artifacts");
    let cluster = ClusterSpec::env("D", 1000.0)?;
    let manifest = Manifest::load(&artifacts)?;
    let cnn = manifest.model("cnn")?;
    let micro = cnn.microbatch;
    let hw = *cnn.config.get("hw").unwrap() as usize;
    let ch = *cnn.config.get("in_ch").unwrap() as usize;
    let classes = *cnn.config.get("classes").unwrap() as usize;

    let cfg = TrainConfig::new(micro * 4, micro);
    let c = Coordinator::for_artifact_model(&artifacts, "cnn", cluster, cfg)?;
    let out = c.plan()?;
    println!("== Asteroid end-to-end CNN training ==");
    println!("cluster : {}", c.cluster.describe());
    println!("plan    : {}", out.plan.describe(&c.cluster));

    let mut data = VisionTask::new(hw, ch, classes, micro, 7);
    let stats = c.train(
        &out.plan,
        &TrainOpts {
            steps,
            opt: OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 },
            seed: 7,
            emulate: None,
            log_every: 10,
            initial_params: None,
        },
        &mut data,
    )?;

    let mut table = Table::new("e2e CNN loss curve", &["step", "loss"]);
    for (i, l) in stats.losses.iter().enumerate() {
        table.row(vec![i.to_string(), format!("{l:.4}")]);
    }
    table.write_csv(std::path::Path::new("results"), "e2e_cnn_loss")?;

    let first = stats.losses.first().unwrap();
    let last = stats.losses.last().unwrap();
    println!(
        "loss {first:.4} (ln {classes} = {:.3}) -> {last:.4}; {:.1} samples/s",
        (classes as f64).ln(),
        stats.samples_per_sec
    );
    anyhow::ensure!(*last < *first, "loss should decrease");
    Ok(())
}
