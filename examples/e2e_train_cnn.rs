//! End-to-end CNN training: the vision counterpart of e2e_train_lm —
//! the CIFAR-style CNN through a planner-chosen hybrid pipeline, one
//! `Session` + `PjrtBackend`.
//!
//!     cargo run --release --features pjrt --example e2e_train_cnn [steps]

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::metrics::Table;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::OptimizerCfg;
use asteroid::session::{PjrtBackend, Session};

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(60);
    let artifacts = std::path::PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)?;
    let cnn = manifest.model("cnn")?;
    let micro = cnn.microbatch;
    let classes = cnn.cfg_usize("classes")?;

    let session = Session::builder()
        .artifact_model(&artifacts, "cnn")
        .cluster(ClusterSpec::env("D", 1000.0)?)
        .train(TrainConfig::new(micro * 4, micro))
        .steps(steps)
        .optimizer(OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 })
        .seed(7)
        .log_every(10)
        .build()?;
    println!("== Asteroid end-to-end CNN training ==");
    println!("cluster : {}", session.cluster().describe());
    println!("plan    : {}", session.plan().describe(session.cluster()));

    // The backend synthesises the vision task stream (hw/in_ch/classes)
    // from the manifest config.
    let report = session.run(&mut PjrtBackend::new())?;

    let mut table = Table::new("e2e CNN loss curve", &["step", "loss"]);
    for (i, l) in report.losses.iter().enumerate() {
        table.row(vec![i.to_string(), format!("{l:.4}")]);
    }
    table.write_csv(std::path::Path::new("results"), "e2e_cnn_loss")?;

    let first = report.first_loss().unwrap();
    let last = report.last_loss().unwrap();
    println!(
        "loss {first:.4} (ln {classes} = {:.3}) -> {last:.4}; {:.1} samples/s",
        (classes as f64).ln(),
        report.throughput
    );
    anyhow::ensure!(last < first, "loss should decrease");
    Ok(())
}
