//! Quickstart: plan, simulate, and really train a small transformer LM
//! with Asteroid's hybrid pipeline parallelism.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::coordinator::Coordinator;
use asteroid::data::LmTask;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::{OptimizerCfg, TrainOpts};

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");

    // 1. A heterogeneous edge cluster (paper Env D: 1x TX2 + 3x Nano).
    let cluster = ClusterSpec::env("D", 100.0)?;
    println!("cluster: {}", cluster.describe());

    // 2. The AOT-compiled LM (see python/compile/) + training config.
    let manifest = Manifest::load(&artifacts)?;
    let lm = manifest.model("lm")?;
    let micro = lm.microbatch;
    let vocab = *lm.config.get("vocab").unwrap() as usize;
    let seq = *lm.config.get("seq").unwrap() as usize;
    let cfg = TrainConfig::new(micro * 4, micro);
    let c = Coordinator::for_artifact_model(&artifacts, "lm", cluster, cfg)?;

    // 3. Planning phase: Algorithm 2 picks stages / groups / allocations.
    let out = c.plan()?;
    println!("plan:    {}", out.plan.describe(&c.cluster));
    println!("predicted {:.1} samples/s", out.predicted_throughput);

    // 4. Simulated execution (event-accurate schedule).
    let sim = c.simulate(&out.plan);
    println!("simulated {:.1} samples/s on the edge cluster model", sim.throughput);

    // 5. Real execution through the PJRT pipeline engine.
    let mut data = LmTask::new(vocab, seq, micro, 42);
    let stats = c.train(
        &out.plan,
        &TrainOpts { steps: 12, opt: OptimizerCfg::sgd(0.05), log_every: 3, ..Default::default() },
        &mut data,
    )?;
    println!(
        "real HPP training: loss {:.3} -> {:.3} at {:.1} samples/s (host)",
        stats.losses.first().unwrap(),
        stats.losses.last().unwrap(),
        stats.samples_per_sec,
    );
    Ok(())
}
