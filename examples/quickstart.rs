//! Quickstart: one `Session` from model + cluster to a plan, a priced
//! schedule, and (with `--features pjrt`) real HPP training of a small
//! transformer LM.
//!
//!     make artifacts && cargo run --release --features pjrt --example quickstart

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::model::from_manifest::Manifest;
use asteroid::planner::Planner;
use asteroid::session::{PjrtBackend, Session, SimBackend};

fn main() -> Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");

    // 1. A heterogeneous edge cluster (paper Env D: 1x TX2 + 3x Nano).
    let cluster = ClusterSpec::env("D", 100.0)?;
    println!("cluster: {}", cluster.describe());

    // 2. The AOT-compiled LM (see python/compile/): the manifest knows
    //    its compiled micro-batch and config.  Config lookups are
    //    fallible — a stale manifest errors instead of panicking.
    let manifest = Manifest::load(&artifacts)?;
    let lm = manifest.model("lm")?;
    let micro = lm.microbatch;
    println!(
        "model:   lm (vocab {}, seq {}, micro-batch {micro})",
        lm.cfg_usize("vocab")?,
        lm.cfg_usize("seq")?
    );

    // 3. Build the session: preprocessing + planning in one step.
    //    Algorithm 2 picks stages / groups / allocations.
    let session = Session::builder()
        .artifact_model(&artifacts, "lm")
        .cluster(cluster)
        .train(TrainConfig::new(micro * 4, micro))
        .planner(Planner::Asteroid)
        .steps(12)
        .log_every(3)
        .build()?;
    println!("plan:    {}", session.plan().describe(session.cluster()));
    println!(
        "predicted {:.1} samples/s",
        session.outcome().predicted_throughput
    );

    // 4. Simulated execution (event-accurate schedule pricing).
    let sim = session.run(&mut SimBackend::default())?;
    println!(
        "simulated {:.1} samples/s on the edge cluster model",
        sim.throughput
    );

    // 5. Real execution through the PJRT pipeline engine — same
    //    session, different backend.  (Needs `--features pjrt` and a
    //    real xla binding; the backend synthesises the LM task stream
    //    from the manifest.)
    let report = session.run(&mut PjrtBackend::new())?;
    println!(
        "real HPP training: loss {:.3} -> {:.3} at {:.1} samples/s (host)",
        report.first_loss().unwrap(),
        report.last_loss().unwrap(),
        report.throughput,
    );
    Ok(())
}
