//! End-to-end validation driver (DESIGN.md): train the transformer LM
//! for a few hundred HPP-Rounds through the full stack — Pallas-kernel
//! HLO artifacts, planner-chosen hybrid pipeline, multi-worker 1F1B
//! with gradient accumulation, AllReduce and SGD — and log the loss
//! curve to results/e2e_lm_loss.csv.  One `Session`, the `PjrtBackend`
//! does the rest.
//!
//!     cargo run --release --features pjrt --example e2e_train_lm [steps] [--emulate]

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::metrics::Table;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::OptimizerCfg;
use asteroid::session::{PjrtBackend, Session};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(200);
    let emulate = args.iter().any(|a| a == "--emulate");

    let artifacts = std::path::PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)?;
    let lm = manifest.model("lm")?;
    let micro = lm.microbatch;
    let vocab = lm.cfg_usize("vocab")?;
    let seq = lm.cfg_usize("seq")?;
    let params = lm.total_params();

    let session = Session::builder()
        .artifact_model(&artifacts, "lm")
        .cluster(ClusterSpec::env("B", 1000.0)?)
        .train(TrainConfig::new(micro * 8, micro)) // M = 8 micro-batches
        .steps(steps)
        .optimizer(OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 })
        .seed(42)
        .emulate(emulate)
        .log_every(10)
        .build()?;
    println!("== Asteroid end-to-end LM training ==");
    println!("model   : {params} params, vocab {vocab}, seq {seq}, micro-batch {micro}");
    println!("cluster : {}", session.cluster().describe());
    println!("plan    : {}", session.plan().describe(session.cluster()));
    println!(
        "steps   : {steps} HPP-Rounds x {} samples",
        session.plan().samples_per_round()
    );

    let t0 = std::time::Instant::now();
    let report = session.run(&mut PjrtBackend::new())?;
    let wall = t0.elapsed().as_secs_f64();

    let mut table = Table::new("e2e LM loss curve", &["step", "loss", "round_s"]);
    for (i, (l, s)) in report.losses.iter().zip(&report.round_secs).enumerate() {
        table.row(vec![i.to_string(), format!("{l:.4}"), format!("{s:.3}")]);
    }
    table.write_csv(std::path::Path::new("results"), "e2e_lm_loss")?;

    let first = report.first_loss().unwrap();
    let last = report.last_loss().unwrap();
    let best = report.losses.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "\nloss    : {first:.4} (ln V = {:.4}) -> {last:.4} (best {best:.4})",
        (vocab as f64).ln()
    );
    println!("tput    : {:.1} samples/s over {wall:.0}s wall", report.throughput);
    println!("curve   : results/e2e_lm_loss.csv");
    anyhow::ensure!(last < first - 1.0, "loss should fall well below initial");
    println!("OK: all three layers compose (pallas kernels -> stage HLOs -> rust HPP runtime)");
    Ok(())
}
