//! End-to-end validation driver (DESIGN.md): train the transformer LM
//! for a few hundred HPP-Rounds through the full stack — Pallas-kernel
//! HLO artifacts, planner-chosen hybrid pipeline, multi-worker 1F1B
//! with gradient accumulation, AllReduce and SGD — and log the loss
//! curve to results/e2e_lm_loss.csv.
//!
//!     cargo run --release --example e2e_train_lm [steps] [--emulate]

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::coordinator::Coordinator;
use asteroid::data::LmTask;
use asteroid::metrics::Table;
use asteroid::model::from_manifest::Manifest;
use asteroid::pipeline::{OptimizerCfg, TrainOpts};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(200);
    let emulate = args.iter().any(|a| a == "--emulate");

    let artifacts = std::path::PathBuf::from("artifacts");
    let cluster = ClusterSpec::env("B", 1000.0)?;
    let manifest = Manifest::load(&artifacts)?;
    let lm = manifest.model("lm")?;
    let micro = lm.microbatch;
    let vocab = *lm.config.get("vocab").unwrap() as usize;
    let seq = *lm.config.get("seq").unwrap() as usize;
    let params = lm.total_params();

    let cfg = TrainConfig::new(micro * 8, micro); // M = 8 micro-batches
    let c = Coordinator::for_artifact_model(&artifacts, "lm", cluster, cfg)?;
    let out = c.plan()?;
    println!("== Asteroid end-to-end LM training ==");
    println!("model   : {} params, vocab {vocab}, seq {seq}, micro-batch {micro}", params);
    println!("cluster : {}", c.cluster.describe());
    println!("plan    : {}", out.plan.describe(&c.cluster));
    println!("steps   : {steps} HPP-Rounds x {} samples", out.plan.samples_per_round());

    let opts = TrainOpts {
        steps,
        opt: OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 },
        seed: 42,
        emulate: if emulate { Some(c.cluster.clone()) } else { None },
        log_every: 10,
        initial_params: None,
    };
    let mut data = LmTask::new(vocab, seq, micro, 42);
    let t0 = std::time::Instant::now();
    let stats = c.train(&out.plan, &opts, &mut data)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut table = Table::new("e2e LM loss curve", &["step", "loss", "round_s"]);
    for (i, (l, s)) in stats.losses.iter().zip(&stats.round_secs).enumerate() {
        table.row(vec![i.to_string(), format!("{l:.4}"), format!("{s:.3}")]);
    }
    table.write_csv(std::path::Path::new("results"), "e2e_lm_loss")?;

    let first = stats.losses.first().unwrap();
    let last = stats.losses.last().unwrap();
    let best = stats.losses.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("\nloss    : {first:.4} (ln V = {:.4}) -> {last:.4} (best {best:.4})", (vocab as f64).ln());
    println!("tput    : {:.1} samples/s over {wall:.0}s wall", stats.samples_per_sec);
    println!("curve   : results/e2e_lm_loss.csv");
    anyhow::ensure!(*last < first - 1.0, "loss should fall well below initial");
    println!("OK: all three layers compose (pallas kernels -> stage HLOs -> rust HPP runtime)");
    Ok(())
}
