//! Heterogeneous planning tour: run Asteroid's planner over every paper
//! model x environment and print the chosen HPP configurations
//! (Fig. 12) side by side with the baselines it beats (Table 4's
//! qualitative story).  Every method — ours and baselines — goes
//! through the same `Session` builder; only the `Planner` choice
//! changes.
//!
//!     cargo run --release --example heterogeneous_planning

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::model::zoo;
use asteroid::planner::baselines::Method;
use asteroid::planner::Planner;
use asteroid::session::{Session, SimBackend};

fn main() -> Result<()> {
    for model in zoo::all() {
        println!("\n=== {} ({} layers, {} params) ===",
                 model.name, model.num_layers(),
                 asteroid::util::stats::human_bytes(model.total_weight_bytes() / 4 * 4));
        for (env, mbps) in [("A", 100.0), ("B", 100.0), ("B", 1000.0), ("C", 100.0)] {
            let cluster = ClusterSpec::env(env, mbps)?;
            let cfg = match model.name.as_str() {
                "resnet50" => TrainConfig::new(256, 8),
                "bert-small" => TrainConfig::new(2048, 8),
                _ => TrainConfig::new(2048, 32),
            };
            let build = |planner: Planner| {
                Session::builder()
                    .model(&model.name)
                    .cluster(cluster.clone())
                    .train(cfg.clone())
                    .planner(planner)
                    .build()
            };
            let ours = build(Planner::Asteroid)?;
            let sim = ours.run(&mut SimBackend::default())?;
            println!("\n  Env {env} @ {mbps:.0} Mbps ({})", cluster.describe());
            println!("    Asteroid: {}", ours.plan().describe(&cluster));
            println!("              {:.1} samples/s (sim)", sim.throughput);
            for method in [Method::DataParallel, Method::GpipePP] {
                match build(Planner::Baseline(method)) {
                    Ok(s) => {
                        let r = s.run(&mut SimBackend::default())?;
                        println!(
                            "    {:<9}: {:.1} samples/s  (Asteroid {:.1}x)",
                            method.name(),
                            r.throughput,
                            sim.throughput / r.throughput
                        );
                    }
                    Err(e) => println!("    {:<9}: infeasible ({e})", method.name()),
                }
            }
        }
    }
    Ok(())
}
