//! Fault-tolerance walkthrough (paper §3.4 / Figs. 9, 16, 17): build a
//! `Session` on Env D, show the replication topology, run a live
//! heartbeat monitor while a device "dies", then compare the two
//! recovery mechanisms by attaching the matching `FaultSpec`s.
//!
//!     cargo run --release --example fault_tolerance_demo

use std::time::Duration;

use anyhow::Result;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::fault::{
    replication_plan, BackupStore, HeartbeatCfg, HeartbeatMonitor, Liveness, RecoverySource,
};
use asteroid::session::{FaultSpec, RecoveryKind, Session, SimBackend};

fn main() -> Result<()> {
    let cluster = ClusterSpec::env("D", 100.0)?;
    let session = Session::builder()
        .model("efficientnet-b1")
        .cluster(cluster.clone())
        .train(TrainConfig::new(2048, 32))
        .build()?;
    let plan = session.plan();
    println!("plan: {}", plan.describe(&cluster));
    println!(
        "throughput before failure: {:.1} samples/s\n",
        session.run(&mut SimBackend::default())?.throughput
    );

    // --- replication topology (Fig. 9 left) ------------------------------
    let repl = replication_plan(session.model(), plan);
    let mut store = BackupStore::new();
    for (p, src) in repl.sources.iter().enumerate() {
        match src {
            RecoverySource::IntraStageReplica => {
                println!("stage {p}: replica-protected (weights live on peers)");
            }
            RecoverySource::BackupNode { holder } => {
                println!(
                    "stage {p}: checkpoints {} to backup node {}",
                    asteroid::util::stats::human_bytes(repl.checkpoint_bytes[p]),
                    cluster.devices[*holder].name
                );
                // live checkpoint of (dummy) stage weights
                store.checkpoint(p, vec![0.0; (repl.checkpoint_bytes[p] / 4) as usize]);
            }
        }
    }

    // --- heartbeat detection (live) --------------------------------------
    let hb = HeartbeatCfg {
        interval: Duration::from_millis(50),
        miss_threshold: 2,
        probe_rtt: Duration::from_millis(10),
    };
    let devices = plan.devices();
    let mut monitor = HeartbeatMonitor::new(hb, &devices);
    let dying = devices[1];
    println!("\ndevice {} stops heartbeating ...", cluster.devices[dying].name);
    for tick in 0..5 {
        std::thread::sleep(Duration::from_millis(40));
        for &d in &devices {
            if d != dying {
                monitor.beat(d);
            }
        }
        for &d in monitor.suspects().iter() {
            println!("  t+{}ms: device {} suspected -> probing", 40 * (tick + 1), d);
            monitor.confirm_failure(d);
        }
    }
    assert_eq!(monitor.liveness(dying), Liveness::Confirmed);
    println!("device {} confirmed failed (detection model: {:.2}s)\n",
             cluster.devices[dying].name, hb.detection_time());

    // --- recovery comparison (Figs. 16/17) --------------------------------
    // Device-exit + recovery is a declarative property of the session:
    // same session, two FaultSpecs, one backend.
    let mut reports = Vec::new();
    for kind in [RecoveryKind::Lightweight, RecoveryKind::Heavy] {
        let run = session
            .clone()
            .with_fault(FaultSpec::device(dying).with_recovery(kind))
            .run(&mut SimBackend::default())?;
        let r = run.recoveries.into_iter().next().unwrap().report;
        println!(
            "{:<12} detect {:.2}s + restore {:.2}s + replan {:.2}s + migrate {:.2}s = {:.2}s",
            r.mechanism, r.detection_s, r.restore_s, r.replan_s, r.migration_s, r.total_s()
        );
        println!("             resumes at {:.1} samples/s with {}",
                 r.new_throughput, r.new_plan.describe(&cluster));
        reports.push(r);
    }
    let (lite, heavy) = (&reports[0], &reports[1]);
    println!(
        "\nlightweight replay recovers {:.1}x faster with {:.0}% of heavy's throughput",
        heavy.total_s() / lite.total_s(),
        100.0 * lite.new_throughput / heavy.new_throughput
    );
    Ok(())
}
